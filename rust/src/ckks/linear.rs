//! Homomorphic linear algebra on slot vectors: the BSGS diagonal method.
//!
//! `hom_linear` evaluates an arbitrary complex `slots x slots` matrix on an
//! encrypted vector using O(2*sqrt(s)) rotations instead of O(s) — the
//! primitive behind CoeffToSlot / SlotToCoeff in bootstrapping and the
//! JKLS-style matrix multiplications of the BERT-Tiny workload (SVI-A).
//!
//! The walk is expressed as a **program builder**
//! ([`hom_linear_program`]): `hom_linear` builds the BSGS DAG and runs it
//! through `Evaluator::run_program`, so the baby-step rotations — all
//! reading the same input register — share **one** hoisted key-switch
//! digit decomposition, and the per-digit NTTs batch through the MLT
//! engine. [`hom_linear_eager`] keeps the original one-op-at-a-time walk
//! as the bit-exactness oracle and benchmark baseline.

use super::encoding::{encode_with, Complex};
use super::keys::{bsgs_geometry, MissingKey};
use super::ops::{Ciphertext, Evaluator};
use super::program::{FheProgram, ProgramBuilder, ProgramError, Reg};

/// A dense complex matrix acting on the slot vector.
#[derive(Debug, Clone, PartialEq)]
pub struct SlotMatrix {
    pub dim: usize,
    /// Row-major entries (dim x dim).
    pub entries: Vec<Complex>,
}

impl SlotMatrix {
    pub fn zeros(dim: usize) -> Self {
        Self {
            dim,
            entries: vec![Complex::zero(); dim * dim],
        }
    }

    pub fn at(&self, r: usize, c: usize) -> Complex {
        self.entries[r * self.dim + c]
    }

    pub fn set(&mut self, r: usize, c: usize, v: Complex) {
        self.entries[r * self.dim + c] = v;
    }

    pub fn identity(dim: usize) -> Self {
        let mut m = Self::zeros(dim);
        for i in 0..dim {
            m.set(i, i, Complex::new(1.0, 0.0));
        }
        m
    }

    /// The d-th generalized diagonal: diag_d[j] = M[j][(j + d) mod dim].
    pub fn diagonal(&self, d: usize) -> Vec<Complex> {
        (0..self.dim)
            .map(|j| self.at(j, (j + d) % self.dim))
            .collect()
    }

    pub fn matvec(&self, v: &[Complex]) -> Vec<Complex> {
        (0..self.dim)
            .map(|r| {
                let mut acc = Complex::zero();
                for c in 0..self.dim {
                    acc = acc.add(self.at(r, c).mul(v[c]));
                }
                acc
            })
            .collect()
    }

    pub fn matmul(&self, other: &SlotMatrix) -> SlotMatrix {
        assert_eq!(self.dim, other.dim);
        let mut out = SlotMatrix::zeros(self.dim);
        for r in 0..self.dim {
            for c in 0..self.dim {
                let mut acc = Complex::zero();
                for k in 0..self.dim {
                    acc = acc.add(self.at(r, k).mul(other.at(k, c)));
                }
                out.set(r, c, acc);
            }
        }
        out
    }
}

/// Rotate a plaintext complex vector left by `k` (matches `Evaluator::rotate`).
fn rot_plain(v: &[Complex], k: usize) -> Vec<Complex> {
    let s = v.len();
    (0..s).map(|j| v[(j + k) % s]).collect()
}

/// The BSGS walk for `m` with empty diagonals skipped: every giant step
/// `j` that has at least one nonzero diagonal in its column group,
/// paired with the baby indices `i` whose diagonal `d = i + j*g` is
/// nonzero. `None` when the matrix has no nonzero diagonal at all.
///
/// The ONE place the skip logic lives: [`hom_linear_program`] executes
/// this plan and [`bsgs_used_steps`] (the key check
/// `FheProgram::validate` runs for `OpCode::HomLinear`) derives from
/// it, so admission and execution cannot drift.
fn bsgs_plan(m: &SlotMatrix) -> Option<Vec<(usize, Vec<usize>)>> {
    let s = m.dim;
    let (g, outer) = bsgs_geometry(s);
    let mut plan = Vec::new();
    for j in 0..outer {
        let mut babies = Vec::new();
        for i in 0..g {
            let d = i + j * g;
            if d >= s {
                break;
            }
            if m.diagonal(d).iter().all(|c| c.abs() < 1e-12) {
                continue; // sparse matrices skip empty diagonals entirely
            }
            babies.push(i);
        }
        if !babies.is_empty() {
            plan.push((j, babies));
        }
    }
    if plan.is_empty() {
        None
    } else {
        Some(plan)
    }
}

/// The rotation steps the BSGS walk actually performs for this matrix:
/// the used baby steps `i` plus the nonzero giant steps `(j*g) % s`,
/// derived from [`bsgs_plan`]. `None` when the matrix has no nonzero
/// diagonal at all.
pub(crate) fn bsgs_used_steps(m: &SlotMatrix) -> Option<Vec<usize>> {
    let s = m.dim;
    let (g, _) = bsgs_geometry(s);
    let plan = bsgs_plan(m)?;
    let mut steps = Vec::new();
    for (j, babies) in &plan {
        for &i in babies {
            if i != 0 {
                steps.push(i);
            }
        }
        let r = (j * g) % s;
        if r != 0 {
            steps.push(r);
        }
    }
    steps.sort_unstable();
    steps.dedup();
    Some(steps)
}

/// Build the BSGS walk for `m` as an [`FheProgram`] over one input
/// register `"x"` at the given `level` (output `"y"`). Diagonal
/// plaintexts are encoded at `level` so the raw products line up with
/// the input's chain.
///
/// All baby-step rotations read the input register, so
/// `Evaluator::run_program` shares **one** hoisted digit decomposition
/// across every baby step — the GME/Cheddar rotation-batching win;
/// each giant step rotates its own freshly accumulated register
/// (inherently unsharable). Panics if the matrix has no nonzero
/// diagonal — reject that at admission, as the coordinator does.
pub fn hom_linear_program(ev: &Evaluator, m: &SlotMatrix, level: usize) -> FheProgram {
    let s = ev.ctx.params.slots();
    assert_eq!(m.dim, s, "matrix must match the slot count");
    let (g, _) = bsgs_geometry(s);
    let plan = bsgs_plan(m).expect("matrix had no nonzero diagonal");
    let mut b = ProgramBuilder::new();
    let x = b.input("x");
    let mut baby: Vec<Option<Reg>> = vec![None; g];
    baby[0] = Some(x);
    let mut total: Option<Reg> = None;
    for (j, babies) in &plan {
        let mut inner: Option<Reg> = None;
        for &i in babies {
            let diag = m.diagonal(i + j * g);
            // Pre-rotate the diagonal by -jg (i.e. right-rotate by jg).
            let shifted = rot_plain(&diag, s - (j * g) % s);
            let br = match baby[i] {
                Some(r) => r,
                None => {
                    let r = b.rotate(x, i);
                    baby[i] = Some(r);
                    r
                }
            };
            let pt = encode_with(&ev.ctx, &ev.encoder, &shifted, level, ev.ctx.scale);
            // Multiply WITHOUT rescaling yet (sum first, rescale once).
            let term = b.mul_plain_raw(br, pt);
            inner = Some(match inner {
                None => term,
                Some(acc) => b.add(acc, term),
            });
        }
        let inner = inner.expect("plan rows are non-empty");
        let rotated = if (j * g) % s == 0 {
            inner
        } else {
            b.rotate(inner, (j * g) % s)
        };
        total = Some(match total {
            None => rotated,
            Some(acc) => b.add(acc, rotated),
        });
    }
    let total = total.expect("plan is non-empty");
    let y = b.rescale(total);
    b.output("y", y);
    b.finish()
}

/// Evaluate `M . slots(ct)` homomorphically (baby-step giant-step).
///
/// Identity: M.v = sum_d diag_d(M) o rot_d(v). With d = i + j*g,
/// rot_{i+jg}(v) = rot_{jg}(rot_i(v)) and pre-rotating the diagonal by -jg:
/// M.v = sum_j rot_{jg}( sum_i rot_{-jg}(diag_{i+jg}) o rot_i(v) ).
/// Consumes one multiplicative level. Needs the BSGS Galois keys (see
/// `keys::bsgs_steps`) in the evaluator's public key set; fails with the
/// typed [`MissingKey`] error otherwise.
///
/// Runs as an [`FheProgram`] ([`hom_linear_program`]) so the baby-step
/// rotation fan-out shares one hoisted key-switch decomposition —
/// bit-identical to [`hom_linear_eager`], the retained one-op-at-a-time
/// oracle.
pub fn hom_linear(
    ev: &Evaluator,
    ct: &Ciphertext,
    m: &SlotMatrix,
) -> Result<Ciphertext, MissingKey> {
    let prog = hom_linear_program(ev, m, ct.level);
    match ev.run_program(&prog, std::slice::from_ref(ct)) {
        Ok(mut out) => Ok(out.pop().expect("program declares one output")),
        Err(ProgramError::MissingKey { key, .. }) => Err(key),
        // The builder emits structurally valid programs; anything else
        // indicates the same misuse the eager walk asserted on.
        Err(other) => panic!("hom_linear program rejected: {other}"),
    }
}

/// The original eager BSGS walk — one rotation at a time through
/// [`Evaluator::rotate`], no decomposition sharing. Kept as the
/// bit-exactness oracle for the program-backed [`hom_linear`] and as the
/// "before" side of `benches/program.rs`.
pub fn hom_linear_eager(
    ev: &Evaluator,
    ct: &Ciphertext,
    m: &SlotMatrix,
) -> Result<Ciphertext, MissingKey> {
    let s = ev.ctx.params.slots();
    assert_eq!(m.dim, s, "matrix must match the slot count");
    let (g, outer) = bsgs_geometry(s);

    // Baby steps: rot_i(ct) for i in 0..g (skip unused ones lazily).
    let mut baby: Vec<Option<Ciphertext>> = vec![None; g];
    let get_baby =
        |i: usize, baby: &mut Vec<Option<Ciphertext>>| -> Result<Ciphertext, MissingKey> {
            if baby[i].is_none() {
                baby[i] = Some(if i == 0 { ct.clone() } else { ev.rotate(ct, i)? });
            }
            Ok(baby[i].clone().unwrap())
        };

    let mut total: Option<Ciphertext> = None;
    for j in 0..outer {
        let mut inner: Option<Ciphertext> = None;
        for i in 0..g {
            let d = i + j * g;
            if d >= s {
                break;
            }
            let diag = m.diagonal(d);
            if diag.iter().all(|c| c.abs() < 1e-12) {
                continue; // sparse matrices skip empty diagonals entirely
            }
            // Pre-rotate the diagonal by -jg (i.e. right-rotate by jg).
            let shifted = rot_plain(&diag, s - (j * g) % s);
            let b = get_baby(i, &mut baby)?;
            let pt = encode_with(&ev.ctx, &ev.encoder, &shifted, b.level, ev.ctx.scale);
            // Multiply WITHOUT rescaling yet (sum first, rescale once).
            let term = ev.mul_plain_raw(&b, &pt);
            inner = Some(match inner {
                None => term,
                Some(acc) => ev.add(&acc, &term),
            });
        }
        if let Some(inner) = inner {
            let rotated = if (j * g) % s == 0 {
                inner
            } else {
                ev.rotate(&inner, (j * g) % s)?
            };
            total = Some(match total {
                None => rotated,
                Some(acc) => ev.add(&acc, &rotated),
            });
        }
    }
    let total = total.expect("matrix had no nonzero diagonal");
    Ok(ev.rescale(&total))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::client::{Decryptor, Encryptor, KeyGen};
    use crate::ckks::keys::{bsgs_steps, EvalKeySpec};
    use crate::ckks::params::{CkksContext, CkksParams};
    use crate::util::rng::Pcg64;
    use std::sync::Arc;

    fn fixture() -> (Evaluator, Encryptor, Decryptor, Pcg64) {
        let ctx = CkksContext::new(CkksParams::toy());
        let mut rng = Pcg64::new(0xBEEF);
        let kg = KeyGen::new(&ctx, &mut rng);
        let spec = EvalKeySpec::none().with_rotations(&bsgs_steps(ctx.params.slots()));
        let keys = kg.eval_key_set(&ctx, &spec, &mut rng);
        let enc = kg.encryptor();
        let dec = kg.decryptor();
        (Evaluator::new(ctx, Arc::new(keys)), enc, dec, rng)
    }

    fn ramp(s: usize) -> Vec<Complex> {
        (0..s)
            .map(|i| Complex::new((i as f64 / s as f64) - 0.5, 0.0))
            .collect()
    }

    fn max_err(a: &[Complex], b: &[Complex]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| Complex::new(x.re - y.re, x.im - y.im).abs())
            .fold(0.0, f64::max)
    }

    #[test]
    fn identity_matrix_is_noop() {
        let (ev, enc, dec, mut rng) = fixture();
        let s = ev.ctx.params.slots();
        let z = ramp(s);
        let ct = enc.encrypt_slots(&ev.ctx, &z, 3, &mut rng);
        let out = hom_linear(&ev, &ct, &SlotMatrix::identity(s)).unwrap();
        assert_eq!(out.level, 2);
        let back = dec.decrypt_to_slots(&ev.ctx, &out);
        assert!(max_err(&z, &back) < 1e-3, "err={}", max_err(&z, &back));
    }

    #[test]
    fn permutation_matrix() {
        let (ev, enc, dec, mut rng) = fixture();
        let s = ev.ctx.params.slots();
        let z = ramp(s);
        // Cyclic shift-by-3 as a matrix.
        let mut m = SlotMatrix::zeros(s);
        for r in 0..s {
            m.set(r, (r + 3) % s, Complex::new(1.0, 0.0));
        }
        let ct = enc.encrypt_slots(&ev.ctx, &z, 3, &mut rng);
        let out = hom_linear(&ev, &ct, &m).unwrap();
        let back = dec.decrypt_to_slots(&ev.ctx, &out);
        let want = m.matvec(&z);
        assert!(max_err(&want, &back) < 1e-3);
    }

    #[test]
    fn missing_bsgs_key_surfaces_as_error() {
        // An evaluator with no Galois keys cannot run a dense transform.
        let (ev, enc, _dec, mut rng) = fixture();
        let s = ev.ctx.params.slots();
        let z = ramp(s);
        let ct = enc.encrypt_slots(&ev.ctx, &z, 3, &mut rng);
        let bare = Evaluator::without_keys(CkksContext::new(CkksParams::toy()));
        let mut m = SlotMatrix::zeros(s);
        for r in 0..s {
            m.set(r, (r + 1) % s, Complex::new(1.0, 0.0));
        }
        assert!(hom_linear(&bare, &ct, &m).is_err());
    }

    #[test]
    fn random_dense_complex_matrix() {
        let (ev, enc, dec, mut rng) = fixture();
        let s = ev.ctx.params.slots();
        let z = ramp(s);
        let mut m = SlotMatrix::zeros(s);
        for r in 0..s {
            for c in 0..s {
                m.set(
                    r,
                    c,
                    Complex::new(
                        (rng.f64() - 0.5) / s as f64,
                        (rng.f64() - 0.5) / s as f64,
                    ),
                );
            }
        }
        let ct = enc.encrypt_slots(&ev.ctx, &z, 3, &mut rng);
        let out = hom_linear(&ev, &ct, &m).unwrap();
        let back = dec.decrypt_to_slots(&ev.ctx, &out);
        let want = m.matvec(&z);
        assert!(max_err(&want, &back) < 1e-3, "err={}", max_err(&want, &back));
    }

    #[test]
    fn program_backed_hom_linear_is_bit_identical_to_eager() {
        let (ev, enc, dec, mut rng) = fixture();
        let s = ev.ctx.params.slots();
        let z = ramp(s);
        let mut m = SlotMatrix::zeros(s);
        for r in 0..s {
            for c in 0..s {
                m.set(
                    r,
                    c,
                    Complex::new((rng.f64() - 0.5) / s as f64, (rng.f64() - 0.5) / s as f64),
                );
            }
        }
        let ct = enc.encrypt_slots(&ev.ctx, &z, 3, &mut rng);
        let hoisted = hom_linear(&ev, &ct, &m).unwrap();
        let eager = hom_linear_eager(&ev, &ct, &m).unwrap();
        assert_eq!(hoisted, eager, "hoisting must not change a single bit");
        let back = dec.decrypt_to_slots(&ev.ctx, &hoisted);
        let want = m.matvec(&z);
        assert!(max_err(&want, &back) < 1e-3);
    }

    #[test]
    fn used_steps_mirror_the_walk() {
        // Dense matrix: every declared BSGS step is used.
        let s = 16usize;
        let mut dense = SlotMatrix::zeros(s);
        for r in 0..s {
            for c in 0..s {
                dense.set(r, c, Complex::new(1.0, 0.0));
            }
        }
        assert_eq!(
            bsgs_used_steps(&dense).unwrap(),
            crate::ckks::keys::bsgs_steps(s)
        );
        // A single-diagonal (permutation) matrix uses only its own steps.
        let mut perm = SlotMatrix::zeros(s);
        for r in 0..s {
            perm.set(r, (r + 3) % s, Complex::new(1.0, 0.0));
        }
        assert_eq!(bsgs_used_steps(&perm).unwrap(), vec![3]);
        // All-zero matrix: nothing to do.
        assert!(bsgs_used_steps(&SlotMatrix::zeros(s)).is_none());
    }

    #[test]
    fn matvec_and_matmul_agree() {
        let mut m1 = SlotMatrix::identity(4);
        m1.set(0, 3, Complex::new(2.0, 0.0));
        let m2 = SlotMatrix::identity(4);
        let prod = m1.matmul(&m2);
        let v = vec![
            Complex::new(1.0, 0.0),
            Complex::new(2.0, 0.0),
            Complex::new(3.0, 0.0),
            Complex::new(4.0, 0.0),
        ];
        let a = prod.matvec(&v);
        let b = m1.matvec(&m2.matvec(&v));
        assert!(max_err(&a, &b) < 1e-12);
    }
}
