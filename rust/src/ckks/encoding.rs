//! CKKS encoding: the canonical embedding between complex slot vectors and
//! integer polynomials (SII-A, Table II's plaintexts).
//!
//! Slots are ordered along the `5^j mod 2N` coset so that the Galois
//! automorphism `x -> x^(5^k)` acts as a cyclic rotation by k slots — the
//! property `Rotate` (Table II) relies on.
//!
//! The transform here is the direct O(N * N/2) evaluation; it is the
//! *client-side* operation (encode/encrypt, decrypt/decode) and never on
//! the paper's measured server path, so clarity wins over speed. A
//! fused-FFT fast path can be swapped in behind the same interface.

use super::params::CkksContext;
use super::poly::{Format, RnsPoly};

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Complex {
    pub re: f64,
    pub im: f64,
}

impl Complex {
    pub fn new(re: f64, im: f64) -> Self {
        Self { re, im }
    }

    pub fn zero() -> Self {
        Self { re: 0.0, im: 0.0 }
    }

    pub fn conj(self) -> Self {
        Self { re: self.re, im: -self.im }
    }

    pub fn mul(self, o: Self) -> Self {
        Self {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }

    pub fn add(self, o: Self) -> Self {
        Self { re: self.re + o.re, im: self.im + o.im }
    }

    pub fn abs(self) -> f64 {
        self.re.hypot(self.im)
    }
}

/// Precomputed root powers and the 5^j slot ordering for one ring dim.
pub struct Encoder {
    pub n: usize,
    /// zeta^t for t in 0..2N, zeta = exp(i*pi/N) the primitive 2N-th root.
    roots: Vec<Complex>,
    /// rot_group[j] = 5^j mod 2N — evaluation exponent of slot j.
    rot_group: Vec<usize>,
}

impl Encoder {
    pub fn new(n: usize) -> Self {
        let two_n = 2 * n;
        let roots = (0..two_n)
            .map(|t| {
                let theta = std::f64::consts::PI * t as f64 / n as f64;
                Complex::new(theta.cos(), theta.sin())
            })
            .collect();
        let mut rot_group = Vec::with_capacity(n / 2);
        let mut g = 1usize;
        for _ in 0..n / 2 {
            rot_group.push(g);
            g = (g * 5) % two_n;
        }
        Self { n, roots, rot_group }
    }

    /// Real coefficient vector (length N, f64) embedding `z` at scale
    /// `delta`: m_k = (2/N) * Re( sum_j delta*z_j * zeta^(-k*5^j) ).
    pub fn embed(&self, z: &[Complex], delta: f64) -> Vec<f64> {
        let slots = self.n / 2;
        assert!(z.len() <= slots, "too many slots for N={}", self.n);
        let two_n = 2 * self.n;
        let mut out = vec![0f64; self.n];
        for (k, coeff) in out.iter_mut().enumerate() {
            let mut acc = 0f64;
            for (j, &zj) in z.iter().enumerate() {
                // zeta^(-k * 5^j) = conj(zeta^(k*5^j))
                let e = (k * self.rot_group[j]) % two_n;
                let w = self.roots[e].conj();
                acc += zj.re * w.re - zj.im * w.im;
            }
            *coeff = acc * delta * 2.0 / self.n as f64;
        }
        out
    }

    /// Evaluate the real coefficient vector at the slot points / delta.
    pub fn project(&self, coeffs: &[f64], delta: f64) -> Vec<Complex> {
        let slots = self.n / 2;
        let two_n = 2 * self.n;
        let mut out = vec![Complex::zero(); slots];
        for (j, slot) in out.iter_mut().enumerate() {
            let mut acc = Complex::zero();
            for (k, &c) in coeffs.iter().enumerate() {
                let e = (k * self.rot_group[j]) % two_n;
                acc = acc.add(Complex::new(c * self.roots[e].re, c * self.roots[e].im));
            }
            *slot = Complex::new(acc.re / delta, acc.im / delta);
        }
        out
    }
}

/// Encode a complex slot vector into an RNS plaintext polynomial at the
/// given level (coefficient format).
pub fn encode(ctx: &CkksContext, z: &[Complex], level: usize) -> RnsPoly {
    let encoder = Encoder::new(ctx.params.n);
    encode_with(ctx, &encoder, z, level, ctx.scale)
}

pub fn encode_with(
    ctx: &CkksContext,
    encoder: &Encoder,
    z: &[Complex],
    level: usize,
    delta: f64,
) -> RnsPoly {
    let coeffs = encoder.embed(z, delta);
    let chain = ctx.chain_at(level);
    let mut poly = RnsPoly::zero(&ctx.tower, &chain, Format::Coeff);
    for (i, &ci) in chain.iter().enumerate() {
        let m = ctx.tower.contexts[ci].modulus;
        for (dst, &c) in poly.limbs[i].iter_mut().zip(&coeffs) {
            let r = c.round();
            *dst = if r >= 0.0 {
                m.reduce_u128(r as u128)
            } else {
                m.neg(m.reduce_u128((-r) as u128))
            };
        }
    }
    poly
}

/// Decode an RNS plaintext polynomial back to complex slots.
///
/// Coefficients are lifted to centered representatives via the *first*
/// limb only (valid while the plaintext magnitude stays below q_0/2, the
/// standard decoding regime).
pub fn decode(ctx: &CkksContext, poly: &RnsPoly, delta: f64) -> Vec<Complex> {
    assert_eq!(poly.format, Format::Coeff, "decode needs Coeff");
    let encoder = Encoder::new(ctx.params.n);
    decode_with(ctx, &encoder, poly, delta)
}

pub fn decode_with(
    ctx: &CkksContext,
    encoder: &Encoder,
    poly: &RnsPoly,
    delta: f64,
) -> Vec<Complex> {
    let m = ctx.tower.contexts[poly.chain[0]].modulus;
    let q = m.value();
    let coeffs: Vec<f64> = poly.limbs[0]
        .iter()
        .map(|&x| {
            if x > q / 2 {
                -((q - x) as f64)
            } else {
                x as f64
            }
        })
        .collect();
    encoder.project(&coeffs, delta)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::params::CkksParams;

    fn max_err(a: &[Complex], b: &[Complex]) -> f64 {
        a.iter()
            .zip(b)
            .map(|(x, y)| Complex::new(x.re - y.re, x.im - y.im).abs())
            .fold(0.0, f64::max)
    }

    fn ramp(slots: usize) -> Vec<Complex> {
        (0..slots)
            .map(|i| Complex::new(0.01 * i as f64 - 0.5, 0.002 * i as f64))
            .collect()
    }

    #[test]
    fn encode_decode_roundtrip() {
        let ctx = CkksContext::new(CkksParams::toy());
        let z = ramp(ctx.params.slots());
        let pt = encode(&ctx, &z, ctx.max_level());
        let back = decode(&ctx, &pt, ctx.scale);
        assert!(max_err(&z, &back) < 1e-6, "err={}", max_err(&z, &back));
    }

    #[test]
    fn embedding_is_real_and_additive() {
        let enc = Encoder::new(64);
        let z1 = ramp(32);
        let z2: Vec<Complex> = ramp(32).iter().map(|c| c.mul(Complex::new(2.0, 0.0))).collect();
        let e1 = enc.embed(&z1, 1024.0);
        let e2 = enc.embed(&z2, 1024.0);
        let sum: Vec<Complex> = z1
            .iter()
            .zip(&z2)
            .map(|(a, b)| a.add(*b))
            .collect();
        let es = enc.embed(&sum, 1024.0);
        for k in 0..64 {
            assert!((e1[k] + e2[k] - es[k]).abs() < 1e-9);
        }
    }

    #[test]
    fn automorphism_rotates_slots() {
        // The defining property of the 5^j ordering: applying x -> x^5 to
        // the *coefficients* cyclically shifts the slot vector by one.
        let ctx = CkksContext::new(CkksParams::toy());
        let n = ctx.params.n;
        let z = ramp(n / 2);
        let pt = encode(&ctx, &z, 0);
        let rotated = pt.automorphism(5, &ctx.tower);
        let back = decode(&ctx, &rotated, ctx.scale);
        // back[j] should equal z[j+1 mod slots]
        let want: Vec<Complex> = (0..n / 2).map(|j| z[(j + 1) % (n / 2)]).collect();
        assert!(max_err(&back, &want) < 1e-6, "err={}", max_err(&back, &want));
    }

    #[test]
    fn conjugation_automorphism() {
        // x -> x^(2N-1) conjugates every slot.
        let ctx = CkksContext::new(CkksParams::toy());
        let n = ctx.params.n;
        let z = ramp(n / 2);
        let pt = encode(&ctx, &z, 0);
        let conj = pt.automorphism(2 * n - 1, &ctx.tower);
        let back = decode(&ctx, &conj, ctx.scale);
        let want: Vec<Complex> = z.iter().map(|c| c.conj()).collect();
        assert!(max_err(&back, &want) < 1e-6);
    }

    #[test]
    fn scale_carries_through() {
        let ctx = CkksContext::new(CkksParams::toy());
        let z = vec![Complex::new(0.25, 0.0); ctx.params.slots()];
        let pt = encode(&ctx, &z, 1);
        // Decoding at twice the scale halves the values.
        let back = decode(&ctx, &pt, ctx.scale * 2.0);
        assert!((back[0].re - 0.125).abs() < 1e-6);
    }

    #[test]
    fn partial_slot_vectors_pad_with_zero() {
        let ctx = CkksContext::new(CkksParams::toy());
        let z = vec![Complex::new(1.0, 0.0); 3];
        let pt = encode(&ctx, &z, 0);
        let back = decode(&ctx, &pt, ctx.scale);
        assert!((back[0].re - 1.0).abs() < 1e-6);
        assert!(back[5].abs() < 1e-6);
    }
}
