//! Negacyclic Number Theoretic Transform over `Z_q[x]/(x^N + 1)`.
//!
//! Three implementations coexist, matching the paper's framing:
//!
//! * [`NttTable::forward`]/[`NttTable::inverse`] — the natural-order
//!   entry points. They ride the **limb-batched MLT formulation** (see
//!   below) through [`NttTable::forward_batch`]/[`NttTable::inverse_batch`],
//!   which accept any number of same-modulus polynomials and execute both
//!   matrix passes of the Bailey 4-step decomposition as one
//!   `ModLinKernel` call over the concatenated column blocks — the
//!   schedule TensorFHE/WarpDrive/FHECore map onto matrix units.
//! * [`NttTable::forward_iterative`]/[`NttTable::inverse_iterative`] — the
//!   iterative O(N log N) Cooley-Tukey / Gentleman-Sande pair with
//!   Harvey/Shoup butterflies, kept as the bit-exactness oracle for the
//!   MLT path (and still the engine behind the bit-reversed
//!   [`NttTable::forward_br`]/[`NttTable::inverse_br`] pair that
//!   `RnsPoly::to_eval`/`to_coeff` run per limb — what CUDA cores run in
//!   FIDESlib).
//! * [`NttTable::forward_4step`] — the single-poly 4-step wrapper
//!   (Eq. 2/4) over the batch core. The matrix passes execute on the
//!   shared MLT engine via a cached [`FourStepPlan`] (Vandermonde/twiddle
//!   tables built once per (table, N1, direction));
//!   [`NttTable::forward_4step_reference`] keeps the uncached original.
//! * `ntt_naive` (tests) — the O(N^2) definition, the ground truth.
//!
//! Convention: `forward` consumes natural (coefficient) order and produces
//! **natural evaluation order** `a_hat[k] = a(psi^(2k+1))`; `inverse` maps
//! back. Internally the iterative transforms work in bit-reversed order
//! and the tables fold the permutation into the twiddle indexing, so no
//! explicit reorder pass is needed for the roundtrip; pointwise products
//! are order-agnostic either way.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::modarith::Modulus;
use super::modlin::ModLinKernel;
use super::prime::root_of_unity;

/// Cached constants for one `N = N1 x N2` factorization of the 4-step
/// NTT: the two Vandermonde matrices compiled as [`ModLinKernel`]s (Shoup
/// pairs + lazy accumulation), plus the step-2 twiddle matrix with Shoup
/// companions. Built once per (table, N1, direction) and shared across
/// calls — the seed recomputed every `m.pow` per element per call. The
/// inverse-direction plan holds the same structures over `w^-1`.
#[derive(Debug)]
pub struct FourStepPlan {
    pub n1: usize,
    pub n2: usize,
    /// Step 1: `B = W1 @ A`, `W1[k1][j1] = w_N1^(j1*k1)` (N1 output rows).
    w1: ModLinKernel,
    /// Step 3 (transposed): `D^T = W2 @ C^T`, `W2[k2][j2] = w_N2^(j2*k2)`.
    w2: ModLinKernel,
    /// Step 2 twiddles `tw[k1*N2 + j2] = w_N^(j2*k1)` with Shoup words.
    tw: Vec<u64>,
    tw_shoup: Vec<u64>,
}

/// Keyed by `(N1, inverse)` — forward and inverse directions cache
/// independent Vandermonde/twiddle sets.
type PlanCache = Arc<Mutex<HashMap<(usize, bool), Arc<FourStepPlan>>>>;

/// Negacyclic pre-twist `psi^j` with Shoup words — N1-independent, so
/// cached once per table (not per plan) and shared across all splits.
#[derive(Debug)]
struct TwistTable {
    pows: Vec<u64>,
    shoup: Vec<u64>,
}

/// Precomputed twiddles for one (N, q) pair.
#[derive(Debug, Clone)]
pub struct NttTable {
    pub n: usize,
    pub m: Modulus,
    /// psi^bitrev(i) for the CT forward pass (natural -> bit-reversed).
    psi_br: Vec<u64>,
    psi_br_shoup: Vec<u64>,
    /// psi^-bitrev(i) for the GS inverse pass.
    ipsi_br: Vec<u64>,
    ipsi_br_shoup: Vec<u64>,
    n_inv: u64,
    n_inv_shoup: u64,
    /// 2N-th root used to build all tables (kept for the 4-step path).
    pub psi: u64,
    /// Lazily built [`FourStepPlan`]s keyed by (N1, direction) (shared
    /// across clones).
    plans: PlanCache,
    /// Lazily built pre-twist table (shared across plans and clones).
    twist: Arc<OnceLock<TwistTable>>,
    /// Inverse post-twist `n^-1 * psi^-j` (shared like `twist`).
    itwist: Arc<OnceLock<TwistTable>>,
}

fn bitrev(x: usize, bits: u32) -> usize {
    x.reverse_bits() >> (usize::BITS - bits)
}

impl NttTable {
    pub fn new(n: usize, q: u64) -> Self {
        assert!(n.is_power_of_two() && n >= 2);
        let _ = Modulus::new(q); // validate q early
        let psi = root_of_unity(2 * n as u64, q);
        Self::with_psi(n, q, psi)
    }

    /// Build tables from an explicitly chosen 2N-th root (deterministic
    /// across layers — the Python side and PJRT artifacts must agree).
    pub fn with_psi(n: usize, q: u64, psi: u64) -> Self {
        let m = Modulus::new(q);
        debug_assert_eq!(m.pow(psi, n as u64), q - 1, "psi^N must be -1");
        let bits = n.trailing_zeros();
        let ipsi = m.inv(psi);

        let mut pw = vec![0u64; n];
        let mut ipw = vec![0u64; n];
        let mut cur = 1u64;
        let mut icur = 1u64;
        for i in 0..n {
            pw[i] = cur;
            ipw[i] = icur;
            cur = m.mul(cur, psi);
            icur = m.mul(icur, ipsi);
        }
        let mut psi_br = vec![0u64; n];
        let mut ipsi_br = vec![0u64; n];
        for i in 0..n {
            psi_br[i] = pw[bitrev(i, bits)];
            ipsi_br[i] = ipw[bitrev(i, bits)];
        }
        let psi_br_shoup = psi_br.iter().map(|&w| m.shoup(w)).collect();
        let ipsi_br_shoup = ipsi_br.iter().map(|&w| m.shoup(w)).collect();
        let n_inv = m.inv(n as u64);
        Self {
            n,
            m,
            psi_br,
            psi_br_shoup,
            ipsi_br,
            ipsi_br_shoup,
            n_inv,
            n_inv_shoup: m.shoup(n_inv),
            psi,
            plans: Arc::new(Mutex::new(HashMap::new())),
            twist: Arc::new(OnceLock::new()),
            itwist: Arc::new(OnceLock::new()),
        }
    }

    /// The balanced `N1 ~ sqrt(N)` split the batch entry points default
    /// to — it minimizes the cached plan footprint (O(N1^2 + N2^2)).
    pub fn balanced_split(n: usize) -> usize {
        1usize << (n.trailing_zeros() / 2)
    }

    /// In-place forward negacyclic NTT (natural in, natural out), riding
    /// the limb-batched MLT formulation (batch of one). Bit-identical to
    /// [`Self::forward_iterative`], the oracle.
    pub fn forward(&self, a: &mut [u64]) {
        self.forward_batch(&mut [a]);
    }

    /// Forward-transform a batch of same-modulus polynomials through the
    /// 4-step decomposition, with each matrix pass executed as **one**
    /// [`ModLinKernel`] call over the concatenation of every polynomial's
    /// column block — the limb-batched schedule the MLT engine tiles and
    /// parallelizes across `(row, tile)` pairs.
    pub fn forward_batch(&self, polys: &mut [&mut [u64]]) {
        self.dft4_batch(polys, Self::balanced_split(self.n), false);
    }

    /// [`Self::forward_batch`] with an explicit `N1` split.
    pub fn forward_batch_with(&self, polys: &mut [&mut [u64]], n1: usize) {
        self.dft4_batch(polys, n1, false);
    }

    /// The iterative Cooley-Tukey path (natural in, natural out) — the
    /// bit-exactness oracle for the MLT-backed [`Self::forward`].
    ///
    /// Decimation-in-time with the psi-fold (Longa-Naehrig): the
    /// negacyclic twist is folded into the twiddle table so no
    /// pre-scaling pass is needed. The body produces the bit-reversed
    /// spectrum; a final permutation restores natural order.
    pub fn forward_iterative(&self, a: &mut [u64]) {
        self.forward_br(a);
        bitrev_permute(a);
    }

    /// Forward NTT leaving the spectrum in bit-reversed order (the form
    /// pointwise kernels consume — one permutation saved per transform).
    pub fn forward_br(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let m = self.m;
        let q = m.value();
        let mut t = self.n;
        let mut mm = 1usize;
        while mm < self.n {
            t >>= 1;
            for i in 0..mm {
                let w = self.psi_br[mm + i];
                let ws = self.psi_br_shoup[mm + i];
                let j1 = 2 * i * t;
                // Split the butterfly pair into two disjoint slices so the
                // inner loop is bounds-check-free and auto-vectorizable
                // (SPerf iteration #3: ~1.5x on the butterfly loop).
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x_ref, y_ref) in lo.iter_mut().zip(hi.iter_mut()) {
                    // Harvey butterfly: (x, y) <- (x + wy, x - wy).
                    let x = *x_ref;
                    let y = m.mul_shoup(*y_ref, w, ws);
                    *x_ref = if x + y >= q { x + y - q } else { x + y };
                    *y_ref = if x >= y { x - y } else { x + q - y };
                }
            }
            mm <<= 1;
        }
    }

    /// In-place inverse negacyclic NTT (natural in, natural out), riding
    /// the limb-batched MLT formulation (batch of one). Bit-identical to
    /// [`Self::inverse_iterative`], the oracle.
    pub fn inverse(&self, a: &mut [u64]) {
        self.inverse_batch(&mut [a]);
    }

    /// Inverse-transform a batch of same-modulus polynomials:
    /// `a[j] = n^-1 psi^-j sum_k a_hat[k] w^-jk`, i.e. the 4-step DFT
    /// over `w^-1` followed by the cached `n^-1 psi^-j` post-twist.
    pub fn inverse_batch(&self, polys: &mut [&mut [u64]]) {
        self.dft4_batch(polys, Self::balanced_split(self.n), true);
    }

    /// [`Self::inverse_batch`] with an explicit `N1` split.
    pub fn inverse_batch_with(&self, polys: &mut [&mut [u64]], n1: usize) {
        self.dft4_batch(polys, n1, true);
    }

    /// The iterative Gentleman-Sande path (natural in, natural out) — the
    /// bit-exactness oracle for the MLT-backed [`Self::inverse`].
    pub fn inverse_iterative(&self, a: &mut [u64]) {
        bitrev_permute(a);
        self.inverse_br(a);
    }

    /// Inverse NTT consuming a bit-reversed spectrum (Gentleman-Sande).
    pub fn inverse_br(&self, a: &mut [u64]) {
        debug_assert_eq!(a.len(), self.n);
        let m = self.m;
        let q = m.value();
        let mut t = 1usize;
        let mut mm = self.n;
        while mm > 1 {
            let h = mm >> 1;
            let mut j1 = 0usize;
            for i in 0..h {
                let w = self.ipsi_br[h + i];
                let ws = self.ipsi_br_shoup[h + i];
                let (lo, hi) = a[j1..j1 + 2 * t].split_at_mut(t);
                for (x_ref, y_ref) in lo.iter_mut().zip(hi.iter_mut()) {
                    let x = *x_ref;
                    let y = *y_ref;
                    let s = if x + y >= q { x + y - q } else { x + y };
                    let d = if x >= y { x - y } else { x + q - y };
                    *x_ref = s;
                    *y_ref = m.mul_shoup(d, w, ws);
                }
                j1 += 2 * t;
            }
            t <<= 1;
            mm = h;
        }
        for x in a.iter_mut() {
            *x = m.mul_shoup(*x, self.n_inv, self.n_inv_shoup);
        }
    }

    /// Build (or fetch) the cached forward 4-step plan for a given N1.
    ///
    /// A plan holds the dense N1xN1 and N2xN2 Vandermonde kernels, so its
    /// footprint is O(N1^2 + N2^2) u64s — minimized by balanced splits
    /// (N1 ~ sqrt(N)). Strongly skewed splits of large rings (e.g.
    /// N1 = 16 at N = 2^16) materialize a huge N2^2 matrix; prefer the
    /// iterative [`Self::forward_iterative`] or a balanced split there.
    pub fn four_step_plan(&self, n1: usize) -> Arc<FourStepPlan> {
        self.plan_dir(n1, false)
    }

    /// Build (or fetch) the cached plan for one `(N1, direction)` pair.
    pub fn plan_dir(&self, n1: usize, inverse: bool) -> Arc<FourStepPlan> {
        let n = self.n;
        let n2 = n / n1;
        assert_eq!(n1 * n2, n, "n1 must divide n");
        let mut cache = self.plans.lock().unwrap();
        cache
            .entry((n1, inverse))
            .or_insert_with(|| Arc::new(self.build_plan(n1, n2, inverse)))
            .clone()
    }

    fn build_plan(&self, n1: usize, n2: usize, inverse: bool) -> FourStepPlan {
        let m = self.m;
        let q = m.value();
        let w_fwd = m.mul(self.psi, self.psi); // w_N = psi^2
        let w = if inverse { m.inv(w_fwd) } else { w_fwd };
        let w1 = m.pow(w, n2 as u64); // w_N1
        let w2 = m.pow(w, n1 as u64); // w_N2

        // Vandermonde rows by iterated multiplication (no per-entry pow):
        // row r of V(base, dim) is the powers of base^r.
        let vand_rows = |base: u64, dim: usize| -> Vec<Vec<u64>> {
            let mut rows = Vec::with_capacity(dim);
            let mut row_base = 1u64; // base^r
            for _ in 0..dim {
                let mut row = Vec::with_capacity(dim);
                let mut cur = 1u64;
                for _ in 0..dim {
                    row.push(cur);
                    cur = m.mul(cur, row_base);
                }
                rows.push(row);
                row_base = m.mul(row_base, base);
            }
            rows
        };
        // `x_bound = q` is tight: matrix-pass inputs are residues of this
        // table's own modulus. For NTT-friendly chains (q < 2^52) that
        // puts both plan kernels on the SIMD lane path (mlt_backend);
        // wider tables fall back to the scalar tile, still bit-exact.
        let w1_kernel = ModLinKernel::from_rows(&vec![m; n1], &vand_rows(w1, n1), q);
        let w2_kernel = ModLinKernel::from_rows(&vec![m; n2], &vand_rows(w2, n2), q);

        // Step-2 twiddles tw[k1*N2 + j2] = w^(j2*k1).
        let mut tw = Vec::with_capacity(n1 * n2);
        let mut w_k1 = 1u64; // w^k1
        for _ in 0..n1 {
            let mut cur = 1u64;
            for _ in 0..n2 {
                tw.push(cur);
                cur = m.mul(cur, w_k1);
            }
            w_k1 = m.mul(w_k1, w);
        }
        let tw_shoup = tw.iter().map(|&t| m.shoup(t)).collect();

        FourStepPlan {
            n1,
            n2,
            w1: w1_kernel,
            w2: w2_kernel,
            tw,
            tw_shoup,
        }
    }

    /// Negacyclic pre-twist powers `psi^j` (built once per table).
    fn twist_table(&self) -> &TwistTable {
        self.twist.get_or_init(|| {
            let m = self.m;
            let mut pows = Vec::with_capacity(self.n);
            let mut cur = 1u64;
            for _ in 0..self.n {
                pows.push(cur);
                cur = m.mul(cur, self.psi);
            }
            let shoup = pows.iter().map(|&p| m.shoup(p)).collect();
            TwistTable { pows, shoup }
        })
    }

    /// Inverse post-twist `n^-1 * psi^-j` (built once per table).
    fn itwist_table(&self) -> &TwistTable {
        self.itwist.get_or_init(|| {
            let m = self.m;
            let ipsi = m.inv(self.psi);
            let mut pows = Vec::with_capacity(self.n);
            let mut cur = self.n_inv;
            for _ in 0..self.n {
                pows.push(cur);
                cur = m.mul(cur, ipsi);
            }
            let shoup = pows.iter().map(|&p| m.shoup(p)).collect();
            TwistTable { pows, shoup }
        })
    }

    /// The Bailey 4-step NTT (Eq. 2/4): reshape N = N1 x N2, matrix pass,
    /// twiddle pass, matrix pass, transpose. This is the formulation that
    /// maps onto Tensor Cores / FHECore; output is identical to
    /// [`Self::forward_iterative`]. Single-poly wrapper over the batch
    /// core ([`Self::forward_batch_with`]).
    pub fn forward_4step(&self, a: &[u64], n1: usize) -> Vec<u64> {
        let mut out = a.to_vec();
        self.forward_batch_with(&mut [&mut out], n1);
        out
    }

    /// The limb-batched 4-step DFT core behind every MLT-path entry
    /// point. Both matrix passes run on the shared MLT engine through the
    /// cached [`FourStepPlan`] — the same kernel that executes base
    /// conversion — with all `B` polynomials' column blocks concatenated
    /// into a single `apply` per pass, and the final transpose folded
    /// into the step-3 orientation (`D^T = W2 @ C^T` flattens directly
    /// into the output layout). `inverse` swaps the Vandermonde base to
    /// `w^-1`, drops the pre-twist and applies the `n^-1 psi^-j`
    /// post-twist instead.
    fn dft4_batch(&self, polys: &mut [&mut [u64]], n1: usize, inverse: bool) {
        if polys.is_empty() {
            return;
        }
        let n = self.n;
        let _span = crate::telemetry::span_with(crate::telemetry::Stage::Ntt, polys.len() as u64);
        let _prim = crate::telemetry::prim_scope(crate::telemetry::Primitive::Ntt);
        crate::telemetry::add_butterfly_equiv(
            polys.len() as u64 * (n as u64 / 2) * n.trailing_zeros() as u64,
        );
        debug_assert!(polys.iter().all(|p| p.len() == n), "poly length != N");
        let plan = self.plan_dir(n1, inverse);
        let (n1, n2) = (plan.n1, plan.n2);
        let b = polys.len();
        let (bn1, bn2) = (b * n1, b * n2);
        let m = self.m;

        // Reshape every poly into its [N1 x N2] block of X (+ the
        // negacyclic pre-twist a[j] *= psi^j on the forward direction).
        let mut xrows = vec![0u64; n1 * bn2];
        for (p, poly) in polys.iter().enumerate() {
            for j1 in 0..n1 {
                let src = &poly[j1 * n2..(j1 + 1) * n2];
                let dst = &mut xrows[j1 * bn2 + p * n2..][..n2];
                if inverse {
                    dst.copy_from_slice(src);
                } else {
                    let tw = self.twist_table();
                    for (j2, (d, &x)) in dst.iter_mut().zip(src).enumerate() {
                        let j = j1 * n2 + j2;
                        *d = m.mul_shoup(x, tw.pows[j], tw.shoup[j]);
                    }
                }
            }
        }

        // Step 1: B = W1 @ X — one MLT call over all B*N2 columns.
        let mut brows = vec![0u64; n1 * bn2];
        {
            let x: Vec<&[u64]> = xrows.chunks(bn2).collect();
            let mut out: Vec<&mut [u64]> = brows.chunks_mut(bn2).collect();
            plan.w1.apply(&x, &mut out);
        }

        // Step 2: twiddle C[k1, j2] = B[k1, j2] * w^(j2 k1) (cached, the
        // same N2-long row serves every poly's block).
        for k1 in 0..n1 {
            let row = &mut brows[k1 * bn2..(k1 + 1) * bn2];
            let tws = &plan.tw[k1 * n2..(k1 + 1) * n2];
            let tss = &plan.tw_shoup[k1 * n2..(k1 + 1) * n2];
            for blk in row.chunks_mut(n2) {
                for ((x, &t), &ts) in blk.iter_mut().zip(tws).zip(tss) {
                    *x = m.mul_shoup(*x, t, ts);
                }
            }
        }

        // Per-poly transpose: C^T[j2][p*N1 + k1] = C[k1][p*N2 + j2].
        let mut crows = vec![0u64; n2 * bn1];
        for k1 in 0..n1 {
            for p in 0..b {
                for j2 in 0..n2 {
                    crows[j2 * bn1 + p * n1 + k1] = brows[k1 * bn2 + p * n2 + j2];
                }
            }
        }

        // Step 3 + 4 fused: D^T = W2 @ C^T — row k2 of each poly's block
        // is out[k2*N1 .. (k2+1)*N1], the transpose-flatten of step 4.
        let mut orows = vec![0u64; n2 * bn1];
        {
            let x: Vec<&[u64]> = crows.chunks(bn1).collect();
            let mut out: Vec<&mut [u64]> = orows.chunks_mut(bn1).collect();
            plan.w2.apply(&x, &mut out);
        }
        for (p, poly) in polys.iter_mut().enumerate() {
            for k2 in 0..n2 {
                poly[k2 * n1..(k2 + 1) * n1]
                    .copy_from_slice(&orows[k2 * bn1 + p * n1..][..n1]);
            }
            if inverse {
                let itw = self.itwist_table();
                for (j, x) in poly.iter_mut().enumerate() {
                    *x = m.mul_shoup(*x, itw.pows[j], itw.shoup[j]);
                }
            }
        }
    }

    /// The original uncached 4-step formulation (per-element `m.pow`
    /// twiddle generation, per-term modular reduction). Kept as the
    /// bit-exactness oracle for the plan-cached path.
    pub fn forward_4step_reference(&self, a: &[u64], n1: usize) -> Vec<u64> {
        let n = self.n;
        let n2 = n / n1;
        assert_eq!(n1 * n2, n, "n1 must divide n");
        let m = self.m;
        let w = m.mul(self.psi, self.psi); // w_N = psi^2
        let w1 = m.pow(w, n2 as u64); // w_N1
        let w2 = m.pow(w, n1 as u64); // w_N2

        // Negacyclic pre-twist: a[j] *= psi^j.
        let mut scaled = vec![0u64; n];
        let mut pj = 1u64;
        for j in 0..n {
            scaled[j] = m.mul(a[j], pj);
            pj = m.mul(pj, self.psi);
        }

        // Step 1: B[k1, j2] = sum_j1 A[j1, j2] w1^(j1 k1) (W1 @ A).
        let vand = |base: u64, dim: usize| -> Vec<u64> {
            let mut v = vec![0u64; dim * dim];
            for r in 0..dim {
                for c in 0..dim {
                    v[r * dim + c] = m.pow(base, (r * c) as u64);
                }
            }
            v
        };
        let w1m = vand(w1, n1);
        let mut b = vec![0u64; n];
        for k1 in 0..n1 {
            for j2 in 0..n2 {
                let mut acc = 0u64;
                for j1 in 0..n1 {
                    let prod = m.mul(w1m[k1 * n1 + j1], scaled[j1 * n2 + j2]);
                    acc = m.add(acc, prod);
                }
                b[k1 * n2 + j2] = acc;
            }
        }

        // Step 2: twiddle C[k1, j2] = B[k1, j2] * w^(j2 k1).
        for k1 in 0..n1 {
            for j2 in 0..n2 {
                let tw = m.pow(w, (j2 * k1) as u64);
                b[k1 * n2 + j2] = m.mul(b[k1 * n2 + j2], tw);
            }
        }

        // Step 3: D[k1, k2] = sum_j2 C[k1, j2] w2^(j2 k2) (C @ W2).
        let w2m = vand(w2, n2);
        let mut d = vec![0u64; n];
        for k1 in 0..n1 {
            for k2 in 0..n2 {
                let mut acc = 0u64;
                for j2 in 0..n2 {
                    let prod = m.mul(b[k1 * n2 + j2], w2m[j2 * n2 + k2]);
                    acc = m.add(acc, prod);
                }
                d[k1 * n2 + k2] = acc;
            }
        }

        // Step 4: out[k1 + k2*N1] = D[k1, k2] (transpose flatten).
        let mut out = vec![0u64; n];
        for k1 in 0..n1 {
            for k2 in 0..n2 {
                out[k1 + k2 * n1] = d[k1 * n2 + k2];
            }
        }
        out
    }

    /// Pointwise product of two bit-reversed (or equally-ordered) spectra.
    pub fn pointwise(&self, a: &[u64], b: &[u64], out: &mut [u64]) {
        let m = self.m;
        for ((o, &x), &y) in out.iter_mut().zip(a).zip(b) {
            *o = m.mul(x, y);
        }
    }
}

/// In-place bit-reversal permutation.
pub fn bitrev_permute(a: &mut [u64]) {
    let n = a.len();
    let bits = n.trailing_zeros();
    for i in 0..n {
        let j = bitrev(i, bits);
        if i < j {
            a.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::prime::ntt_primes;

    #[test]
    fn four_step_plan_kernels_engage_the_simd_lane_path() {
        // Plan kernels declare x_bound = q (inputs are own-modulus
        // residues), so any NTT table over a < 2^52 prime — every
        // production chain — hands its matrix passes to the mlt_backend
        // lane path. A wide 58-bit table must fall back cleanly instead.
        let q45 = ntt_primes(64, 45, 1)[0];
        let plan = NttTable::new(64, q45).build_plan(8, 8, false);
        assert!(plan.w1.lane_flush_bound() > 0, "45-bit plan kernel lane-eligible");
        assert!(plan.w2.lane_flush_bound() > 0);
        let q58 = ntt_primes(64, 58, 1)[0];
        let wide = NttTable::new(64, q58).build_plan(8, 8, false);
        assert_eq!(wide.w1.lane_flush_bound(), 0, "58-bit inputs exceed the lane split");
    }

    fn naive_negacyclic(a: &[u64], psi: u64, q: u64) -> Vec<u64> {
        let m = Modulus::new(q);
        let n = a.len();
        (0..n)
            .map(|k| {
                let mut s = 0u64;
                for j in 0..n {
                    let tw = m.pow(psi, (j * (2 * k + 1)) as u64);
                    s = m.add(s, m.mul(a[j], tw));
                }
                s
            })
            .collect()
    }

    fn rand_poly(n: usize, q: u64, seed: u64) -> Vec<u64> {
        let mut state = seed | 1;
        (0..n)
            .map(|_| {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
                state % q
            })
            .collect()
    }

    #[test]
    fn forward_matches_naive() {
        for n in [8usize, 64, 256] {
            let q = ntt_primes(n, 50, 1)[0];
            let t = NttTable::new(n, q);
            let a = rand_poly(n, q, 0xABCD);
            let want = naive_negacyclic(&a, t.psi, q);
            // The MLT-backed default path and the iterative oracle must
            // both reproduce the O(N^2) definition.
            let mut got = a.clone();
            t.forward(&mut got);
            assert_eq!(got, want, "mlt n={n}");
            let mut got_it = a.clone();
            t.forward_iterative(&mut got_it);
            assert_eq!(got_it, want, "iterative n={n}");
        }
    }

    #[test]
    fn batched_mlt_matches_iterative_bit_for_bit() {
        for n in [16usize, 128, 1024] {
            let q = ntt_primes(n, 55, 1)[0];
            let t = NttTable::new(n, q);
            let polys: Vec<Vec<u64>> =
                (0..5).map(|i| rand_poly(n, q, 0xB00 + i as u64)).collect();

            // Forward: one batched MLT call vs per-poly butterflies.
            let mut batch: Vec<Vec<u64>> = polys.clone();
            {
                let mut refs: Vec<&mut [u64]> =
                    batch.iter_mut().map(|p| p.as_mut_slice()).collect();
                t.forward_batch(&mut refs);
            }
            for (p, poly) in polys.iter().enumerate() {
                let mut want = poly.clone();
                t.forward_iterative(&mut want);
                assert_eq!(batch[p], want, "forward n={n} poly={p}");
            }

            // Inverse: batched MLT must undo it (and match the oracle).
            let spectra = batch.clone();
            {
                let mut refs: Vec<&mut [u64]> =
                    batch.iter_mut().map(|p| p.as_mut_slice()).collect();
                t.inverse_batch(&mut refs);
            }
            assert_eq!(batch, polys, "batched roundtrip n={n}");
            for (p, spec) in spectra.iter().enumerate() {
                let mut want = spec.clone();
                t.inverse_iterative(&mut want);
                assert_eq!(batch[p], want, "inverse n={n} poly={p}");
            }
        }
    }

    #[test]
    fn roundtrip_various_sizes() {
        for n in [2usize, 16, 128, 1024, 4096] {
            let q = ntt_primes(n, 55, 1)[0];
            let t = NttTable::new(n, q);
            let a = rand_poly(n, q, n as u64);
            let mut x = a.clone();
            t.forward(&mut x);
            t.inverse(&mut x);
            assert_eq!(x, a, "n={n}");
        }
    }

    #[test]
    fn roundtrip_br_domain() {
        let n = 512;
        let q = ntt_primes(n, 58, 1)[0];
        let t = NttTable::new(n, q);
        let a = rand_poly(n, q, 7);
        let mut x = a.clone();
        t.forward_br(&mut x);
        t.inverse_br(&mut x);
        assert_eq!(x, a);
    }

    #[test]
    fn four_step_matches_iterative() {
        let n = 256;
        let q = ntt_primes(n, 50, 1)[0];
        let t = NttTable::new(n, q);
        let a = rand_poly(n, q, 99);
        let mut iterative = a.clone();
        t.forward_iterative(&mut iterative);
        for n1 in [2usize, 4, 16, 64] {
            assert_eq!(t.forward_4step(&a, n1), iterative, "n1={n1}");
        }
    }

    #[test]
    fn four_step_cached_is_bit_identical_to_reference() {
        for (n, bits) in [(64usize, 30u32), (256, 45), (128, 58)] {
            let q = ntt_primes(n, bits, 1)[0];
            let t = NttTable::new(n, q);
            let a = rand_poly(n, q, 0x45 + n as u64);
            let mut n1 = 1usize;
            while n1 <= n {
                assert_eq!(
                    t.forward_4step(&a, n1),
                    t.forward_4step_reference(&a, n1),
                    "n={n} bits={bits} n1={n1}"
                );
                n1 *= 4;
            }
        }
    }

    #[test]
    fn four_step_plan_is_cached_and_shared_across_clones() {
        let n = 64;
        let q = ntt_primes(n, 40, 1)[0];
        let t = NttTable::new(n, q);
        let p1 = t.four_step_plan(8);
        let p2 = t.four_step_plan(8);
        assert!(std::sync::Arc::ptr_eq(&p1, &p2), "plan rebuilt");
        let t2 = t.clone();
        let p3 = t2.four_step_plan(8);
        assert!(std::sync::Arc::ptr_eq(&p1, &p3), "clone must share the cache");
    }

    #[test]
    fn polymul_via_ntt_matches_schoolbook() {
        let n = 64;
        let q = ntt_primes(n, 50, 1)[0];
        let m = Modulus::new(q);
        let t = NttTable::new(n, q);
        let a = rand_poly(n, q, 1);
        let b = rand_poly(n, q, 2);

        // Schoolbook in Z_q[x]/(x^n+1).
        let mut want = vec![0u64; n];
        for i in 0..n {
            for j in 0..n {
                let p = m.mul(a[i], b[j]);
                if i + j < n {
                    want[i + j] = m.add(want[i + j], p);
                } else {
                    want[i + j - n] = m.sub(want[i + j - n], p);
                }
            }
        }

        let mut fa = a.clone();
        let mut fb = b.clone();
        t.forward_br(&mut fa);
        t.forward_br(&mut fb);
        let mut fc = vec![0u64; n];
        t.pointwise(&fa, &fb, &mut fc);
        t.inverse_br(&mut fc);
        assert_eq!(fc, want);
    }

    #[test]
    fn ntt_is_linear() {
        let n = 128;
        let q = ntt_primes(n, 45, 1)[0];
        let m = Modulus::new(q);
        let t = NttTable::new(n, q);
        let a = rand_poly(n, q, 3);
        let b = rand_poly(n, q, 4);
        let sum: Vec<u64> = a.iter().zip(&b).map(|(&x, &y)| m.add(x, y)).collect();
        let (mut fa, mut fb, mut fs) = (a.clone(), b.clone(), sum.clone());
        t.forward(&mut fa);
        t.forward(&mut fb);
        t.forward(&mut fs);
        for i in 0..n {
            assert_eq!(fs[i], m.add(fa[i], fb[i]));
        }
    }

    #[test]
    fn constant_poly_transforms_to_constant_spectrum() {
        let n = 32;
        let q = ntt_primes(n, 40, 1)[0];
        let t = NttTable::new(n, q);
        let mut a = vec![0u64; n];
        a[0] = 5; // constant polynomial 5
        t.forward(&mut a);
        assert!(a.iter().all(|&x| x == 5));
    }

    #[test]
    fn pe_width_primes_also_work() {
        // 30-bit primes — the FHECore datapath width.
        let n = 256;
        let q = ntt_primes(n, 30, 1)[0];
        let t = NttTable::new(n, q);
        let a = rand_poly(n, q, 21);
        let mut x = a.clone();
        t.forward(&mut x);
        t.inverse(&mut x);
        assert_eq!(x, a);
    }
}
