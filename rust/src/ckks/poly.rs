//! RNS polynomials: the fundamental CKKS data object.
//!
//! A polynomial in `R_Q = Z_Q[x]/(x^N + 1)` is stored as one residue limb
//! per prime of the active chain (Table I). Limb-level operations are
//! embarrassingly parallel across primes — the property that makes FHE
//! SIMD-friendly on GPUs (SI) — and are parallelized with rayon here.

use std::sync::Arc;

use super::modarith::Modulus;
use super::ntt::NttTable;
use crate::util::threads::{par_for_each_mut_hint, par_map};

/// Domain tag: coefficient (power basis) or evaluation (NTT, bit-reversed).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Format {
    Coeff,
    Eval,
}

/// Per-prime context shared by every polynomial at a given chain index.
#[derive(Debug)]
pub struct LimbContext {
    pub modulus: Modulus,
    pub ntt: NttTable,
}

/// The full tower of limb contexts for a parameter set (Q then P primes).
#[derive(Debug)]
pub struct Tower {
    pub n: usize,
    pub contexts: Vec<Arc<LimbContext>>,
}

impl Tower {
    pub fn new(n: usize, primes: &[u64]) -> Self {
        let contexts = par_map(primes, |&q| {
            Arc::new(LimbContext {
                modulus: Modulus::new(q),
                ntt: NttTable::new(n, q),
            })
        });
        Self { n, contexts }
    }

    pub fn primes(&self) -> Vec<u64> {
        self.contexts.iter().map(|c| c.modulus.value()).collect()
    }
}

/// An RNS polynomial over the first `limbs.len()` primes of a tower.
#[derive(Debug, Clone, PartialEq)]
pub struct RnsPoly {
    pub n: usize,
    pub format: Format,
    /// `limbs[i][j]` = j-th coefficient (or eval slot) modulo prime i.
    pub limbs: Vec<Vec<u64>>,
    /// Indices into the tower's context list, one per limb. This lets a
    /// polynomial live on a *subset* chain (e.g. the P extension base or a
    /// rescaled lower level) while sharing one tower.
    pub chain: Vec<usize>,
}

impl RnsPoly {
    pub fn zero(tower: &Tower, chain: &[usize], format: Format) -> Self {
        Self {
            n: tower.n,
            format,
            limbs: vec![vec![0u64; tower.n]; chain.len()],
            chain: chain.to_vec(),
        }
    }

    /// A zero-limb placeholder, used to initialize reusable scratch slots
    /// (see `keys::KeySwitchScratch`) before their first `copy_from`.
    pub fn empty() -> Self {
        Self {
            n: 0,
            format: Format::Coeff,
            limbs: Vec::new(),
            chain: Vec::new(),
        }
    }

    /// Overwrite `self` with the shape and contents of `src`, reusing the
    /// existing limb allocations where possible (hot-loop `clone`).
    pub fn copy_from(&mut self, src: &RnsPoly) {
        self.n = src.n;
        self.format = src.format;
        self.chain.clear();
        self.chain.extend_from_slice(&src.chain);
        if self.limbs.len() != src.limbs.len() {
            self.limbs.resize_with(src.limbs.len(), Vec::new);
        }
        for (dst, s) in self.limbs.iter_mut().zip(&src.limbs) {
            dst.clear();
            dst.extend_from_slice(s);
        }
    }

    pub fn level(&self) -> usize {
        self.limbs.len()
    }

    /// Heap bytes held by this polynomial's limb allocations (capacity,
    /// not length — this is the memory-budget accounting unit for the
    /// tenancy registry and scratch pool).
    pub fn resident_bytes(&self) -> usize {
        self.limbs
            .iter()
            .map(|l| l.capacity() * std::mem::size_of::<u64>())
            .sum::<usize>()
            + self.chain.capacity() * std::mem::size_of::<usize>()
    }

    fn zip_check(&self, other: &Self) {
        assert_eq!(self.n, other.n);
        assert_eq!(self.format, other.format, "format mismatch");
        assert_eq!(self.chain, other.chain, "chain mismatch");
    }

    /// Elementwise addition (any format).
    pub fn add_assign(&mut self, other: &Self, tower: &Tower) {
        self.zip_check(other);
        let chain = self.chain.clone();
        par_for_each_mut_hint(&mut self.limbs, self.n, |i, a| {
            let m = tower.contexts[chain[i]].modulus;
            for (x, &y) in a.iter_mut().zip(&other.limbs[i]) {
                *x = m.add(*x, y);
            }
        });
    }

    pub fn sub_assign(&mut self, other: &Self, tower: &Tower) {
        self.zip_check(other);
        let chain = self.chain.clone();
        par_for_each_mut_hint(&mut self.limbs, self.n, |i, a| {
            let m = tower.contexts[chain[i]].modulus;
            for (x, &y) in a.iter_mut().zip(&other.limbs[i]) {
                *x = m.sub(*x, y);
            }
        });
    }

    pub fn neg_assign(&mut self, tower: &Tower) {
        let chain = self.chain.clone();
        par_for_each_mut_hint(&mut self.limbs, self.n, |i, a| {
            let m = tower.contexts[chain[i]].modulus;
            for x in a.iter_mut() {
                *x = m.neg(*x);
            }
        });
    }

    /// Pointwise (Hadamard) product — both operands must be in Eval format.
    pub fn mul_assign(&mut self, other: &Self, tower: &Tower) {
        self.zip_check(other);
        assert_eq!(self.format, Format::Eval, "pointwise mul needs Eval");
        let chain = self.chain.clone();
        par_for_each_mut_hint(&mut self.limbs, self.n, |i, a| {
            let m = tower.contexts[chain[i]].modulus;
            for (x, &y) in a.iter_mut().zip(&other.limbs[i]) {
                *x = m.mul(*x, y);
            }
        });
    }

    /// Multiply every limb by a per-limb scalar.
    pub fn scale_assign(&mut self, scalars: &[u64], tower: &Tower) {
        assert_eq!(scalars.len(), self.limbs.len());
        let chain = self.chain.clone();
        par_for_each_mut_hint(&mut self.limbs, self.n, |i, a| {
            let m = tower.contexts[chain[i]].modulus;
            let ss = m.reduce_u64(scalars[i]);
            let sh = m.shoup(ss);
            for x in a.iter_mut() {
                *x = m.mul_shoup(*x, ss, sh);
            }
        });
    }

    /// Transform all limbs to evaluation (NTT, bit-reversed) format.
    pub fn to_eval(&mut self, tower: &Tower) {
        if self.format == Format::Eval {
            return;
        }
        let chain = self.chain.clone();
        par_for_each_mut_hint(&mut self.limbs, self.n, |i, a| {
            tower.contexts[chain[i]].ntt.forward_br(a)
        });
        self.format = Format::Eval;
    }

    /// Transform all limbs back to coefficient format.
    pub fn to_coeff(&mut self, tower: &Tower) {
        if self.format == Format::Coeff {
            return;
        }
        let chain = self.chain.clone();
        par_for_each_mut_hint(&mut self.limbs, self.n, |i, a| {
            tower.contexts[chain[i]].ntt.inverse_br(a)
        });
        self.format = Format::Coeff;
    }

    /// Apply the Galois automorphism `x -> x^g` (coefficient format).
    ///
    /// Coefficient j maps to position `g*j mod 2N` with a sign flip when
    /// the image lands in the upper half — the Frobenius-map data
    /// rearrangement the paper assigns to CUDA cores + LD/ST (SV-C).
    pub fn automorphism(&self, g: usize, tower: &Tower) -> Self {
        assert_eq!(self.format, Format::Coeff, "automorphism needs Coeff");
        let n = self.n;
        let two_n = 2 * n;
        let mut out = self.clone();
        for (limb_idx, limb) in self.limbs.iter().enumerate() {
            let m = tower.contexts[self.chain[limb_idx]].modulus;
            let dst = &mut out.limbs[limb_idx];
            for j in 0..n {
                let t = (g * j) % two_n;
                let (pos, negate) = if t < n { (t, false) } else { (t - n, true) };
                dst[pos] = if negate { m.neg(limb[j]) } else { limb[j] };
            }
        }
        out
    }

    /// Drop the last limb (used by rescale / mod-down).
    pub fn drop_last_limb(&mut self) {
        self.limbs.pop().expect("cannot drop limb of empty poly");
        self.chain.pop();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::prime::ntt_primes;

    fn tower(n: usize, limbs: usize) -> Tower {
        Tower::new(n, &ntt_primes(n, 50, limbs))
    }

    fn rand_poly(tower: &Tower, chain: &[usize], seed: u64) -> RnsPoly {
        let mut p = RnsPoly::zero(tower, chain, Format::Coeff);
        let mut state = seed | 1;
        for (i, limb) in p.limbs.iter_mut().enumerate() {
            let q = tower.contexts[chain[i]].modulus.value();
            for x in limb.iter_mut() {
                state = state.wrapping_mul(6364136223846793005).wrapping_add(99);
                *x = state % q;
            }
        }
        p
    }

    #[test]
    fn eval_roundtrip() {
        let t = tower(128, 3);
        let chain = [0usize, 1, 2];
        let a = rand_poly(&t, &chain, 5);
        let mut b = a.clone();
        b.to_eval(&t);
        assert_eq!(b.format, Format::Eval);
        b.to_coeff(&t);
        assert_eq!(b.limbs, a.limbs);
    }

    #[test]
    fn add_then_sub_is_identity() {
        let t = tower(64, 2);
        let chain = [0usize, 1];
        let a = rand_poly(&t, &chain, 1);
        let b = rand_poly(&t, &chain, 2);
        let mut c = a.clone();
        c.add_assign(&b, &t);
        c.sub_assign(&b, &t);
        assert_eq!(c.limbs, a.limbs);
    }

    #[test]
    fn mul_commutes_with_ntt() {
        // INTT(NTT(a) o NTT(b)) == negacyclic a*b: spot-check via x * x = x^2.
        let t = tower(8, 1);
        let chain = [0usize];
        let mut a = RnsPoly::zero(&t, &chain, Format::Coeff);
        a.limbs[0][1] = 1; // x
        let mut fa = a.clone();
        fa.to_eval(&t);
        let mut prod = fa.clone();
        prod.mul_assign(&fa, &t);
        prod.to_coeff(&t);
        let mut want = vec![0u64; 8];
        want[2] = 1; // x^2
        assert_eq!(prod.limbs[0], want);
    }

    #[test]
    fn automorphism_identity_and_inverse() {
        let t = tower(32, 2);
        let chain = [0usize, 1];
        let a = rand_poly(&t, &chain, 11);
        assert_eq!(a.automorphism(1, &t).limbs, a.limbs);
        // g * g^{-1} = 1 mod 2N: applying both returns the original.
        let g = 5usize;
        let two_n = 64usize;
        let g_inv = (1..two_n).find(|&h| (g * h) % two_n == 1).unwrap();
        let back = a.automorphism(g, &t).automorphism(g_inv, &t);
        assert_eq!(back.limbs, a.limbs);
    }

    #[test]
    fn automorphism_negacyclic_sign() {
        // x -> x^3 sends x^k to x^{3k}, with x^n = -1 wraparound.
        let t = tower(4, 1);
        let chain = [0usize];
        let q = t.contexts[0].modulus.value();
        let mut a = RnsPoly::zero(&t, &chain, Format::Coeff);
        a.limbs[0][2] = 7; // 7x^2
        let out = a.automorphism(3, &t);
        // 3*2 = 6 = 4+2 -> position 2, negated.
        let mut want = vec![0u64; 4];
        want[2] = q - 7;
        assert_eq!(out.limbs[0], want);
    }

    #[test]
    fn scale_assign_matches_mul() {
        let t = tower(16, 2);
        let chain = [0usize, 1];
        let a = rand_poly(&t, &chain, 3);
        let mut b = a.clone();
        b.scale_assign(&[3, 5], &t);
        for (i, limb) in b.limbs.iter().enumerate() {
            let m = t.contexts[i].modulus;
            let s = [3u64, 5][i];
            for (j, &x) in limb.iter().enumerate() {
                assert_eq!(x, m.mul(a.limbs[i][j], s));
            }
        }
    }
}
