//! Modular arithmetic over word-sized primes.
//!
//! Two reduction pipelines coexist, mirroring the paper:
//!
//! * [`Modulus`] — general 64-bit path for the CKKS software substrate
//!   (primes up to 62 bits): SEAL-style Barrett reduction of 128-bit
//!   products with a precomputed `floor(2^128/q)` ratio, plus Harvey/Shoup
//!   multiplication for operands known ahead of time (NTT twiddles).
//! * [`Modulus30`] — the bit-exact FHECore PE pipeline (SIV-C): 30-bit
//!   primes, `mu = floor(2^60/q)`, the same shift/multiply/correct sequence
//!   the Pallas kernel and the Verilog PE implement. Used by the systolic
//!   functional model and for cross-validation against the L1 kernel.

/// A prime modulus with precomputed Barrett constants (general 64-bit path).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Modulus {
    q: u64,
    /// floor(2^128 / q), valid because q is odd (so q never divides 2^128).
    ratio: u128,
}

impl Modulus {
    /// Maximum supported modulus width (bits). 62 keeps `x < q^2 < 2^124`
    /// inside the Barrett validity bound with two corrections.
    pub const MAX_BITS: u32 = 62;

    pub fn new(q: u64) -> Self {
        assert!(q < (1u64 << Self::MAX_BITS), "modulus too wide");
        Self::new_raw(q)
    }

    /// Construction without the CKKS width limit — any odd q < 2^64.
    /// Used by the primality machinery, which reduces modulo arbitrary
    /// odd candidates.
    pub(crate) fn new_raw(q: u64) -> Self {
        assert!(q > 2 && q % 2 == 1, "modulus must be odd and > 2");
        // floor((2^128 - 1)/q) == floor(2^128/q) for odd q.
        let ratio = u128::MAX / q as u128;
        Self { q, ratio }
    }

    #[inline(always)]
    pub fn value(&self) -> u64 {
        self.q
    }

    pub fn bits(&self) -> u32 {
        64 - self.q.leading_zeros()
    }

    /// Barrett-reduce a full 128-bit value modulo q.
    ///
    /// `t = hi128(x * ratio)` underestimates `floor(x/q)` by at most 2, so
    /// two conditional corrections complete the reduction.
    #[inline(always)]
    pub fn reduce_u128(&self, x: u128) -> u64 {
        let t = mulhi_u128(x, self.ratio);
        // Corrections stay in u128: for q close to 2^64 the pre-correction
        // remainder (< 3q) does not fit in a u64.
        let mut r = x - t * self.q as u128;
        if r >= self.q as u128 {
            r -= self.q as u128;
        }
        if r >= self.q as u128 {
            r -= self.q as u128;
        }
        r as u64
    }

    #[inline(always)]
    pub fn reduce_u64(&self, x: u64) -> u64 {
        if x < self.q {
            x
        } else {
            self.reduce_u128(x as u128)
        }
    }

    #[inline(always)]
    pub fn add(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.q && b < self.q);
        let s = a + b;
        if s >= self.q {
            s - self.q
        } else {
            s
        }
    }

    #[inline(always)]
    pub fn sub(&self, a: u64, b: u64) -> u64 {
        debug_assert!(a < self.q && b < self.q);
        if a >= b {
            a - b
        } else {
            a + self.q - b
        }
    }

    #[inline(always)]
    pub fn neg(&self, a: u64) -> u64 {
        debug_assert!(a < self.q);
        if a == 0 {
            0
        } else {
            self.q - a
        }
    }

    #[inline(always)]
    pub fn mul(&self, a: u64, b: u64) -> u64 {
        self.reduce_u128(a as u128 * b as u128)
    }

    pub fn pow(&self, mut base: u64, mut exp: u64) -> u64 {
        let mut acc = 1u64;
        base = self.reduce_u64(base);
        while exp > 0 {
            if exp & 1 == 1 {
                acc = self.mul(acc, base);
            }
            base = self.mul(base, base);
            exp >>= 1;
        }
        acc
    }

    /// Multiplicative inverse by Fermat (q prime).
    pub fn inv(&self, a: u64) -> u64 {
        assert!(a % self.q != 0, "zero has no inverse");
        self.pow(a, self.q - 2)
    }

    /// Precompute the Shoup companion word for a constant multiplicand.
    #[inline]
    pub fn shoup(&self, w: u64) -> u64 {
        debug_assert!(w < self.q);
        (((w as u128) << 64) / self.q as u128) as u64
    }

    /// Harvey/Shoup multiplication `a * w mod q` with precomputed
    /// `w_shoup = floor(w * 2^64 / q)`: two multiplies, one subtract,
    /// one correction. Requires q < 2^63.
    #[inline(always)]
    pub fn mul_shoup(&self, a: u64, w: u64, w_shoup: u64) -> u64 {
        let t = ((a as u128 * w_shoup as u128) >> 64) as u64;
        let r = a
            .wrapping_mul(w)
            .wrapping_sub(t.wrapping_mul(self.q));
        if r >= self.q {
            r - self.q
        } else {
            r
        }
    }
}

/// Top 128 bits of the 256-bit product `a * b` (schoolbook with carries).
#[inline(always)]
fn mulhi_u128(a: u128, b: u128) -> u128 {
    let a_lo = a as u64 as u128;
    let a_hi = a >> 64;
    let b_lo = b as u64 as u128;
    let b_hi = b >> 64;

    let ll = a_lo * b_lo;
    let lh = a_lo * b_hi;
    let hl = a_hi * b_lo;
    let hh = a_hi * b_hi;

    // mid = lh + hl + carry(ll); each term < 2^128, sum needs a carry flag.
    let (mid, c1) = lh.overflowing_add(hl);
    let (mid, c2) = mid.overflowing_add(ll >> 64);
    let carries = ((c1 as u128) + (c2 as u128)) << 64;
    hh + (mid >> 64) + carries
}

/// The FHECore PE reduction pipeline, bit-exact with the hardware of SIV-C
/// and the L1 Pallas kernel: k = 30, primes in `[2^29, 2^30)`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Modulus30 {
    q: u32,
    /// mu = floor(2^60 / q) — the per-PE programmed Barrett constant.
    mu: u64,
}

pub const BARRETT_K: u32 = 30;

impl Modulus30 {
    pub const Q_MIN: u32 = 1 << (BARRETT_K - 1);
    pub const Q_MAX: u32 = 1 << BARRETT_K;

    pub fn new(q: u32) -> Self {
        assert!(
            (Self::Q_MIN..Self::Q_MAX).contains(&q),
            "PE modulus {q} outside [2^29, 2^30)"
        );
        Self {
            q,
            mu: (1u64 << (2 * BARRETT_K)) / q as u64,
        }
    }

    #[inline(always)]
    pub fn value(&self) -> u32 {
        self.q
    }

    #[inline(always)]
    pub fn mu(&self) -> u64 {
        self.mu
    }

    /// The 6-stage PE pipeline in arithmetic form: estimate, multiply-
    /// subtract, two corrections. Valid for any `x < 2^60`.
    #[inline(always)]
    pub fn barrett(&self, x: u64) -> u32 {
        debug_assert!(x < 1u64 << 60);
        let t = ((x >> (BARRETT_K - 1)) * self.mu) >> (BARRETT_K + 1);
        let mut r = x - t * self.q as u64;
        if r >= self.q as u64 {
            r -= self.q as u64;
        }
        if r >= self.q as u64 {
            r -= self.q as u64;
        }
        r as u32
    }

    /// One PE step: `R <- (R + a*b) mod q` (output-stationary MAC).
    #[inline(always)]
    pub fn mac(&self, r: u32, a: u32, b: u32) -> u32 {
        self.barrett(r as u64 + a as u64 * b as u64)
    }

    #[inline(always)]
    pub fn mul(&self, a: u32, b: u32) -> u32 {
        self.barrett(a as u64 * b as u64)
    }

    #[inline(always)]
    pub fn add(&self, a: u32, b: u32) -> u32 {
        let s = a + b; // < 2^31, no overflow
        if s >= self.q {
            s - self.q
        } else {
            s
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const Q60: u64 = (1u64 << 60) - 93; // 60-bit prime
    const Q30: u32 = 0x3FFF_C001; // 30-bit; replaced below by a real prime

    fn modulus30() -> Modulus30 {
        // 1073479681 = 2^30 - 262143*... a known 30-bit NTT prime:
        // q = 1073479681 = 1 + 2^15 * 32760 * ... just verify primality here.
        Modulus30::new(1073479681)
    }

    #[test]
    fn reduce_u128_matches_naive() {
        let m = Modulus::new(Q60);
        let cases: &[u128] = &[
            0,
            1,
            Q60 as u128 - 1,
            Q60 as u128,
            Q60 as u128 + 1,
            u64::MAX as u128,
            (Q60 as u128 - 1) * (Q60 as u128 - 1),
            u128::from(u64::MAX) * u128::from(u64::MAX) >> 4,
        ];
        for &x in cases {
            assert_eq!(m.reduce_u128(x) as u128, x % Q60 as u128, "x={x}");
        }
    }

    #[test]
    fn reduce_u128_randomized() {
        let m = Modulus::new(Q60);
        let mut state = 0x1234_5678_9abc_def0u64;
        for _ in 0..10_000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let a = state % Q60;
            state = state.wrapping_mul(6364136223846793005).wrapping_add(1);
            let b = state % Q60;
            let x = a as u128 * b as u128;
            assert_eq!(m.mul(a, b) as u128, x % Q60 as u128);
        }
    }

    #[test]
    fn shoup_matches_mul() {
        let m = Modulus::new(Q60);
        let mut state = 42u64;
        for _ in 0..2_000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(7);
            let a = state % Q60;
            let w = state.rotate_left(17) % Q60;
            let ws = m.shoup(w);
            assert_eq!(m.mul_shoup(a, w, ws), m.mul(a, w));
        }
    }

    #[test]
    fn pow_and_inv() {
        let m = Modulus::new(Q60);
        assert_eq!(m.pow(3, 0), 1);
        assert_eq!(m.pow(3, 1), 3);
        assert_eq!(m.pow(2, 10), 1024);
        for a in [2u64, 3, 12345, Q60 - 2] {
            let inv = m.inv(a);
            assert_eq!(m.mul(a, inv), 1, "a={a}");
        }
    }

    #[test]
    fn add_sub_neg() {
        let m = Modulus::new(Q60);
        assert_eq!(m.add(Q60 - 1, 1), 0);
        assert_eq!(m.sub(0, 1), Q60 - 1);
        assert_eq!(m.neg(0), 0);
        assert_eq!(m.neg(5), Q60 - 5);
    }

    #[test]
    fn barrett30_matches_mod() {
        let m = modulus30();
        let q = m.value() as u64;
        let mut state = 99u64;
        for _ in 0..10_000 {
            state = state.wrapping_mul(6364136223846793005).wrapping_add(13);
            let x = state % (1u64 << 60);
            assert_eq!(m.barrett(x) as u64, x % q);
        }
    }

    #[test]
    fn pe_mac_semantics() {
        let m = modulus30();
        let q = m.value();
        // R <- (R + a*b) mod q over a chain of MACs == schoolbook dot mod q.
        let a = [123456789u32, q - 1, 7, 0x1fff_ffff];
        let b = [987654321u32, q - 1, q - 2, 3];
        let mut r = 0u32;
        let mut want = 0u64;
        for i in 0..4 {
            r = m.mac(r, a[i] % q, b[i] % q);
            want = (want + (a[i] % q) as u64 * (b[i] % q) as u64) % q as u64;
        }
        assert_eq!(r as u64, want);
    }

    #[test]
    #[should_panic(expected = "outside")]
    fn modulus30_rejects_narrow_prime() {
        Modulus30::new(12289);
    }

    #[test]
    fn q30_constant_is_sane() {
        assert!(Q30 >= Modulus30::Q_MIN);
    }
}
