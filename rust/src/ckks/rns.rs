//! RNS base machinery: fast base conversion (Eq. 3/5), ModUp, ModDown and
//! Rescale — the second-hottest kernel family of the paper (12.6% of
//! runtime in Fig. 1) and the one that exercises FHECore's mixed-moduli
//! systolic columns (SV-B).
//!
//! The conversion itself executes on the shared modulo-linear-transform
//! engine ([`ModLinKernel`]): the Eq. 5 matrix is compiled once at table
//! build (entries reduced per destination prime, Shoup companions
//! precomputed) and applied with lazy u128 accumulation and coefficient-
//! axis tiling. [`BaseConvTable::convert_reference`] keeps the original
//! per-term formulation as the bit-exactness oracle.

use super::modarith::Modulus;
use super::modlin::ModLinKernel;
use super::poly::{Format, RnsPoly, Tower};
use crate::util::threads::par_for_each_mut_hint;

/// Precomputed constants for converting residues from base `P` to base `Q`
/// (both given as context indices into one tower).
#[derive(Debug, Clone)]
pub struct BaseConvTable {
    pub src: Vec<usize>,
    pub dst: Vec<usize>,
    /// `[Phat_j^{-1}]_{p_j}` for each source prime.
    pub phat_inv: Vec<u64>,
    pub phat_inv_shoup: Vec<u64>,
    /// `conv[i][j] = [Phat_j]_{q_i}` — the paper's Eq. 5 left matrix
    /// (kept in row form for the reference path and table inspection).
    pub conv: Vec<Vec<u64>>,
    /// The compiled MLT: reduced `conv` entries + Shoup pairs + lazy
    /// accumulation plan, built once here instead of per `convert` call.
    kernel: ModLinKernel,
}

/// Caller-provided scratch for [`BaseConvTable::convert_into`]: reusing it
/// across calls removes the per-call `alpha * N` staging allocation from
/// the ModUp/ModDown hot loops.
#[derive(Debug, Default)]
pub struct BaseConvScratch {
    y: Vec<Vec<u64>>,
}

impl BaseConvScratch {
    /// Heap bytes held by the staging buffers (memory-budget accounting).
    pub fn resident_bytes(&self) -> usize {
        self.y.iter().map(|v| v.capacity() * std::mem::size_of::<u64>()).sum()
    }
}

impl BaseConvTable {
    pub fn new(tower: &Tower, src: &[usize], dst: &[usize]) -> Self {
        let src_primes: Vec<u64> = src.iter().map(|&i| tower.contexts[i].modulus.value()).collect();
        // Phat_j mod m for arbitrary m, computed without bignums:
        // product of all source primes except j, reduced mod m on the fly.
        let phat_mod = |j: usize, m: Modulus| -> u64 {
            let mut acc = 1u64;
            for (k, &p) in src_primes.iter().enumerate() {
                if k != j {
                    acc = m.mul(acc, m.reduce_u64(p));
                }
            }
            acc
        };
        let phat_inv: Vec<u64> = src
            .iter()
            .enumerate()
            .map(|(j, &ci)| {
                let m = tower.contexts[ci].modulus;
                m.inv(phat_mod(j, m))
            })
            .collect();
        let phat_inv_shoup: Vec<u64> = src
            .iter()
            .zip(&phat_inv)
            .map(|(&ci, &v)| tower.contexts[ci].modulus.shoup(v))
            .collect();
        let conv: Vec<Vec<u64>> = dst
            .iter()
            .map(|&di| {
                let m = tower.contexts[di].modulus;
                (0..src.len()).map(|j| phat_mod(j, m)).collect()
            })
            .collect();
        let dst_moduli: Vec<Modulus> = dst.iter().map(|&di| tower.contexts[di].modulus).collect();
        // Inputs to the MLT are the pre-scaled residues y_j < p_j. The
        // tight bound matters twice: it sizes the scalar flush capacity,
        // and (PR 6) it is what keeps the kernel on the SIMD lane path —
        // mlt_backend's radix-2^26 split needs inputs below 2^52, which
        // every production source base satisfies.
        let x_bound = src_primes.iter().copied().max().expect("empty source base");
        let kernel = ModLinKernel::from_rows(&dst_moduli, &conv, x_bound);
        Self {
            src: src.to_vec(),
            dst: dst.to_vec(),
            phat_inv,
            phat_inv_shoup,
            conv,
            kernel,
        }
    }

    /// Approximate heap bytes held by the precomputed constants. The
    /// compiled [`ModLinKernel`] keeps a reduced copy of the `conv`
    /// matrix plus Shoup companions, so it is counted as two more
    /// matrix-sized planes — an estimate, used only for memory-budget
    /// accounting, not allocation.
    pub fn resident_bytes(&self) -> usize {
        let w = std::mem::size_of::<u64>();
        let matrix: usize = self.conv.iter().map(|row| row.len() * w).sum();
        matrix * 3
            + (self.src.len() + self.dst.len()) * std::mem::size_of::<usize>()
            + (self.phat_inv.len() + self.phat_inv_shoup.len()) * w
    }

    /// HPS fast base conversion of a coefficient-format polynomial
    /// (Eq. 3): `out[i] = sum_j ([x_j * Phat_j^{-1}]_{p_j} * [Phat_j]_{q_i})
    /// mod q_i`, with the well-known `+ e*P` overshoot (0 <= e < alpha).
    ///
    /// This is exactly the "mixed-moduli matrix multiplication" of Eq. 5 —
    /// each output row under a different modulus — which is what FHECore
    /// executes by programming per-column Barrett constants, and what the
    /// [`ModLinKernel`] executes here.
    pub fn convert(&self, poly: &RnsPoly, tower: &Tower) -> RnsPoly {
        let mut scratch = BaseConvScratch::default();
        self.convert_with(poly, tower, &mut scratch)
    }

    /// [`Self::convert`] with caller-provided scratch (hot-loop variant).
    pub fn convert_with(
        &self,
        poly: &RnsPoly,
        tower: &Tower,
        scratch: &mut BaseConvScratch,
    ) -> RnsPoly {
        let mut out = RnsPoly {
            n: poly.n,
            format: Format::Coeff,
            limbs: Vec::new(),
            chain: Vec::new(),
        };
        self.convert_into(poly, tower, scratch, &mut out);
        out
    }

    /// Fully in-place variant: both the `alpha * N` staging buffer and the
    /// `L_out * N` output reuse caller allocations across calls.
    pub fn convert_into(
        &self,
        poly: &RnsPoly,
        tower: &Tower,
        scratch: &mut BaseConvScratch,
        out: &mut RnsPoly,
    ) {
        assert_eq!(poly.format, Format::Coeff, "base conversion needs Coeff");
        assert_eq!(poly.chain, self.src, "polynomial not on the source base");
        let n = poly.n;
        let alpha = self.src.len();
        let _span = crate::telemetry::span_with(crate::telemetry::Stage::BaseConv, alpha as u64);
        let _prim = crate::telemetry::prim_scope(crate::telemetry::Primitive::BaseConv);

        // Stage 1 — elementwise pre-scale: y[j] = [x_j * Phat_j^{-1}]_{p_j}
        // (Shoup pairs precomputed at table build).
        if scratch.y.len() < alpha {
            scratch.y.resize_with(alpha, Vec::new);
        }
        let y = &mut scratch.y[..alpha];
        par_for_each_mut_hint(y, n, |j, buf| {
            let m = tower.contexts[self.src[j]].modulus;
            let (v, vs) = (self.phat_inv[j], self.phat_inv_shoup[j]);
            buf.clear();
            buf.extend(poly.limbs[j].iter().map(|&x| m.mul_shoup(x, v, vs)));
        });

        // Stage 2 — the mixed-moduli MLT: out = Conv . y, one lazy-reduced
        // dot product per (destination limb, coefficient), tiled and
        // parallelized over (limb, tile) pairs by the kernel.
        out.n = n;
        out.format = Format::Coeff;
        out.chain.clear();
        out.chain.extend_from_slice(&self.dst);
        if out.limbs.len() != self.dst.len() {
            out.limbs.resize_with(self.dst.len(), Vec::new);
        }
        for limb in &mut out.limbs {
            limb.resize(n, 0);
        }
        let xr: Vec<&[u64]> = y.iter().map(|v| v.as_slice()).collect();
        let mut or: Vec<&mut [u64]> = out.limbs.iter_mut().map(|v| v.as_mut_slice()).collect();
        self.kernel.apply(&xr, &mut or);
    }

    /// The original per-term Eq. 3 formulation (reduce + Shoup multiply +
    /// modular add per term). Kept as the bit-exactness oracle for the
    /// MLT-backed path; not used on the hot path.
    pub fn convert_reference(&self, poly: &RnsPoly, tower: &Tower) -> RnsPoly {
        assert_eq!(poly.format, Format::Coeff, "base conversion needs Coeff");
        assert_eq!(poly.chain, self.src, "polynomial not on the source base");
        let n = poly.n;
        let alpha = self.src.len();

        let mut y: Vec<Vec<u64>> = vec![Vec::new(); alpha];
        par_for_each_mut_hint(&mut y, n, |j, slot| {
            let m = tower.contexts[self.src[j]].modulus;
            let (v, vs) = (self.phat_inv[j], self.phat_inv_shoup[j]);
            *slot = poly.limbs[j].iter().map(|&x| m.mul_shoup(x, v, vs)).collect();
        });

        let mut limbs: Vec<Vec<u64>> = vec![Vec::new(); self.dst.len()];
        par_for_each_mut_hint(&mut limbs, n, |i, slot| {
            let m = tower.contexts[self.dst[i]].modulus;
            let row = &self.conv[i];
            let mut out = vec![0u64; n];
            for j in 0..alpha {
                // Harvey's precomputed-operand multiply requires the
                // *variable* operand below q too: reduce y (residues of a
                // foreign prime p_j, possibly >= q_i) on entry.
                let c = m.reduce_u64(row[j]);
                let cs = m.shoup(c);
                let yj = &y[j];
                for (o, &v) in out.iter_mut().zip(yj) {
                    let vr = m.reduce_u64(v);
                    *o = m.add(*o, m.mul_shoup(vr, c, cs));
                }
            }
            *slot = out;
        });

        RnsPoly {
            n,
            format: Format::Coeff,
            limbs,
            chain: self.dst.clone(),
        }
    }
}

/// Key-switching / rescale helper constants for one parameter set.
#[derive(Debug)]
pub struct RnsTools {
    /// `q_l^{-1} mod q_i` for every pair (used by rescale: level l -> i).
    pub q_inv: Vec<Vec<u64>>,
    /// Shoup companions of `q_inv`, precomputed at build so rescale's
    /// per-limb loop does no 128-bit division.
    pub q_inv_shoup: Vec<Vec<u64>>,
    /// `[P^{-1}]_{q_i}` where P is the product of the extension primes.
    pub p_inv_mod_q: Vec<u64>,
    pub q_chain: Vec<usize>,
    pub p_chain: Vec<usize>,
    /// Tower context index -> position in `q_chain` (usize::MAX when the
    /// context is not on the Q chain). Replaces the per-limb linear
    /// `position()` scans in rescale/mod_down.
    chain_pos: Vec<usize>,
}

impl RnsTools {
    pub fn new(tower: &Tower, q_chain: &[usize], p_chain: &[usize]) -> Self {
        let nq = q_chain.len();
        let mut q_inv = vec![vec![0u64; nq]; nq];
        let mut q_inv_shoup = vec![vec![0u64; nq]; nq];
        for l in 0..nq {
            let ql = tower.contexts[q_chain[l]].modulus.value();
            for i in 0..nq {
                if i != l {
                    let m = tower.contexts[q_chain[i]].modulus;
                    q_inv[l][i] = m.inv(m.reduce_u64(ql));
                    q_inv_shoup[l][i] = m.shoup(q_inv[l][i]);
                }
            }
        }
        let p_inv_mod_q = q_chain
            .iter()
            .map(|&qi| {
                let m = tower.contexts[qi].modulus;
                let mut acc = 1u64;
                for &pi in p_chain {
                    let p = tower.contexts[pi].modulus.value();
                    acc = m.mul(acc, m.reduce_u64(p));
                }
                m.inv(acc)
            })
            .collect();
        let mut chain_pos = vec![usize::MAX; tower.contexts.len()];
        for (i, &c) in q_chain.iter().enumerate() {
            chain_pos[c] = i;
        }
        Self {
            q_inv,
            q_inv_shoup,
            p_inv_mod_q,
            q_chain: q_chain.to_vec(),
            p_chain: p_chain.to_vec(),
            chain_pos,
        }
    }

    /// Position of a tower context index on the Q chain.
    #[inline]
    fn q_pos(&self, ctx_index: usize) -> usize {
        let pos = self
            .chain_pos
            .get(ctx_index)
            .copied()
            .unwrap_or(usize::MAX);
        assert!(pos != usize::MAX, "context {ctx_index} not on the Q chain");
        pos
    }

    /// Rescale: divide by the last prime of the active chain (Table II).
    ///
    /// `c'_i = (c_i - [c]_{q_l}) * q_l^{-1} mod q_i` — drops one limb and
    /// one level. Input/output in coefficient format. The chain-index
    /// lookup and the Shoup companion of `q_l^{-1}` are precomputed at
    /// table build; the per-limb closure only indexes.
    pub fn rescale(&self, poly: &mut RnsPoly, tower: &Tower) {
        assert_eq!(poly.format, Format::Coeff, "rescale needs Coeff");
        let l = poly.level() - 1;
        assert!(l >= 1, "cannot rescale the last level");
        let last_chain = poly.chain[l];
        let last = poly.limbs[l].clone();
        let q_l = tower.contexts[last_chain].modulus.value();
        let l_pos = self.q_pos(last_chain);
        poly.drop_last_limb();
        let chain = poly.chain.clone();
        let q_inv_row = &self.q_inv[l_pos];
        let q_inv_shoup_row = &self.q_inv_shoup[l_pos];
        let half = q_l / 2;
        let hint = poly.n;
        par_for_each_mut_hint(&mut poly.limbs, hint, |i, limb| {
            let m = tower.contexts[chain[i]].modulus;
            let i_pos = self.q_pos(chain[i]);
            let inv = q_inv_row[i_pos];
            let inv_sh = q_inv_shoup_row[i_pos];
            for (x, &c_last) in limb.iter_mut().zip(&last) {
                // Centered representative of [c]_{q_l} for rounding:
                // subtract c_last (mapped into q_i) then multiply q_l^{-1}.
                let (c_red, negate) = if c_last > half {
                    (q_l - c_last, true)
                } else {
                    (c_last, false)
                };
                let c_mapped = {
                    let r = m.reduce_u64(c_red);
                    if negate {
                        m.neg(r)
                    } else {
                        r
                    }
                };
                let diff = m.sub(*x, c_mapped);
                *x = m.mul_shoup(diff, inv, inv_sh);
            }
        });
    }

    /// ModDown: divide an extended-basis (Q·P) polynomial by P, landing on
    /// Q — the closing step of hybrid key switching.
    pub fn mod_down(
        &self,
        poly: &RnsPoly,
        conv_p_to_q: &BaseConvTable,
        tower: &Tower,
    ) -> RnsPoly {
        assert_eq!(poly.format, Format::Coeff);
        let nq = poly
            .chain
            .iter()
            .filter(|c| self.q_chain.contains(c))
            .count();
        // Split limbs into the Q part and the P part.
        let mut q_part = RnsPoly {
            n: poly.n,
            format: Format::Coeff,
            limbs: poly.limbs[..nq].to_vec(),
            chain: poly.chain[..nq].to_vec(),
        };
        let p_part = RnsPoly {
            n: poly.n,
            format: Format::Coeff,
            limbs: poly.limbs[nq..].to_vec(),
            chain: poly.chain[nq..].to_vec(),
        };
        // (x - BaseConv_{P->Q}([x]_P)) * P^{-1} mod q_i.
        let p_in_q = conv_p_to_q.convert(&p_part, tower);
        q_part.sub_assign(&p_in_q, tower);
        let scalars: Vec<u64> = q_part
            .chain
            .iter()
            .map(|&c| self.p_inv_mod_q[self.q_pos(c)])
            .collect();
        q_part.scale_assign(&scalars, tower);
        q_part
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::prime::ntt_primes;
    use crate::util::rng::Pcg64;

    fn setup(n: usize, nq: usize, np: usize) -> (Tower, Vec<usize>, Vec<usize>) {
        let primes = ntt_primes(n, 45, nq + np);
        let tower = Tower::new(n, &primes);
        let q: Vec<usize> = (0..nq).collect();
        let p: Vec<usize> = (nq..nq + np).collect();
        (tower, q, p)
    }

    #[test]
    fn baseconv_kernels_engage_the_simd_lane_path() {
        // The tight x_bound (max source prime) is what keeps production
        // conversions eligible for the mlt_backend lane decomposition;
        // a regression to a loose bound would silently de-SIMD BConv.
        let (tower, q, p) = setup(32, 3, 2);
        let table = BaseConvTable::new(&tower, &q, &p);
        assert!(
            table.kernel.lane_flush_bound() > 0,
            "45-bit source base must keep the BConv kernel lane-eligible"
        );
    }

    fn rand_src_poly(tower: &Tower, chain: &[usize], seed: u64) -> RnsPoly {
        let mut rng = Pcg64::new(seed);
        let mut poly = RnsPoly::zero(tower, chain, Format::Coeff);
        for (i, limb) in poly.limbs.iter_mut().enumerate() {
            let qi = tower.contexts[chain[i]].modulus.value();
            for x in limb.iter_mut() {
                *x = rng.below(qi);
            }
        }
        poly
    }

    /// CRT-reconstruct coefficient `idx` of an RNS poly into a big integer
    /// represented as u128 (fine for <= 2 limbs of 45 bits in tests).
    fn crt2(tower: &Tower, poly: &RnsPoly, idx: usize) -> u128 {
        assert_eq!(poly.level(), 2);
        let p0 = tower.contexts[poly.chain[0]].modulus.value() as u128;
        let p1m = tower.contexts[poly.chain[1]].modulus;
        let r0 = poly.limbs[0][idx] as u128;
        let r1 = poly.limbs[1][idx];
        // x = r0 + p0 * ((r1 - r0) * p0^{-1} mod p1)
        let p0_inv = p1m.inv(p1m.reduce_u64(p0 as u64));
        let diff = p1m.sub(r1, p1m.reduce_u64(r0 as u64));
        let t = p1m.mul(diff, p0_inv) as u128;
        r0 + p0 * t
    }

    #[test]
    fn baseconv_reproduces_crt_value_mod_targets() {
        let (tower, q, p) = setup(32, 2, 3);
        let table = BaseConvTable::new(&tower, &q, &p);
        let poly = rand_src_poly(&tower, &q, 5);
        // Make the RNS residues consistent with a single integer per slot.
        // (random residues represent *some* integer mod Q; CRT gives it.)
        let out = table.convert(&poly, &tower);
        let q_prod: u128 = q
            .iter()
            .map(|&i| tower.contexts[i].modulus.value() as u128)
            .product();
        for idx in [0usize, 7, 31] {
            let x = crt2(&tower, &poly, idx);
            // Eq. 3 overshoot: out = (x + e*Q) mod p_i with one e in 0..alpha.
            let alpha = q.len() as u128;
            let matches: Vec<u128> = (0..alpha)
                .filter(|&e| {
                    (0..p.len()).all(|i| {
                        let pi = tower.contexts[p[i]].modulus.value() as u128;
                        out.limbs[i][idx] as u128 == (x + e * q_prod) % pi
                    })
                })
                .collect();
            assert_eq!(matches.len(), 1, "coefficient {idx}: no consistent e");
        }
    }

    #[test]
    fn baseconv_zero_is_exact() {
        let (tower, q, p) = setup(16, 2, 2);
        let table = BaseConvTable::new(&tower, &q, &p);
        let poly = RnsPoly::zero(&tower, &q, Format::Coeff);
        let out = table.convert(&poly, &tower);
        for limb in &out.limbs {
            assert!(limb.iter().all(|&x| x == 0));
        }
    }

    #[test]
    fn mlt_convert_is_bit_identical_to_reference() {
        for (n, nq, np) in [(32usize, 3usize, 6usize), (64, 1, 4), (16, 4, 1), (16, 1, 1)] {
            let (tower, q, p) = setup(n, nq, np);
            let table = BaseConvTable::new(&tower, &q, &p);
            let poly = rand_src_poly(&tower, &q, 0xE0 + n as u64);
            let fast = table.convert(&poly, &tower);
            let slow = table.convert_reference(&poly, &tower);
            assert_eq!(fast.limbs, slow.limbs, "n={n} alpha={nq} lout={np}");
            assert_eq!(fast.chain, slow.chain);
        }
    }

    #[test]
    fn convert_into_reuses_scratch_and_output() {
        let (tower, q, p) = setup(32, 2, 3);
        let table = BaseConvTable::new(&tower, &q, &p);
        let mut scratch = BaseConvScratch::default();
        let mut out = RnsPoly::zero(&tower, &p, Format::Coeff);
        // Poison the output to prove every element is overwritten.
        for limb in &mut out.limbs {
            for x in limb.iter_mut() {
                *x = u64::MAX;
            }
        }
        for seed in [1u64, 2, 3] {
            let poly = rand_src_poly(&tower, &q, seed);
            table.convert_into(&poly, &tower, &mut scratch, &mut out);
            let want = table.convert_reference(&poly, &tower);
            assert_eq!(out.limbs, want.limbs, "seed {seed}");
        }
    }

    #[test]
    fn rescale_divides_by_last_prime() {
        // Encode integer x at double-width, rescale, expect round(x / q_l).
        let (tower, q, _) = setup(16, 2, 0);
        let tools = RnsTools::new(&tower, &q, &[]);
        let q0 = tower.contexts[0].modulus.value();
        let q1 = tower.contexts[1].modulus.value();
        let x: u128 = (q1 as u128) * 12345 + 600; // divisible-ish by q1
        let mut poly = RnsPoly::zero(&tower, &q, Format::Coeff);
        poly.limbs[0][0] = (x % q0 as u128) as u64;
        poly.limbs[1][0] = (x % q1 as u128) as u64;
        tools.rescale(&mut poly, &tower);
        assert_eq!(poly.level(), 1);
        // Exact value: (x - [x]_{q1}) / q1 = 12345 (since 600 < q1/2 it
        // rounds down; the centered subtraction keeps the error < 1).
        assert_eq!(poly.limbs[0][0], 12345);
    }

    #[test]
    fn rescale_rounds_toward_nearest() {
        let (tower, q, _) = setup(16, 2, 0);
        let tools = RnsTools::new(&tower, &q, &[]);
        let q0 = tower.contexts[0].modulus.value();
        let q1 = tower.contexts[1].modulus.value();
        // x = 7*q1 + (q1 - 3): remainder is ~q1, so rounding gives 8.
        let x: u128 = (q1 as u128) * 7 + (q1 as u128 - 3);
        let mut poly = RnsPoly::zero(&tower, &q, Format::Coeff);
        poly.limbs[0][0] = (x % q0 as u128) as u64;
        poly.limbs[1][0] = (x % q1 as u128) as u64;
        tools.rescale(&mut poly, &tower);
        assert_eq!(poly.limbs[0][0], 8);
    }

    #[test]
    fn mod_down_undoes_mod_up_for_small_values() {
        // Lift x (< Q) to base Q u P via exact residues, then ModDown after
        // multiplying by P: round-trip recovers x when x*P has no rounding.
        let (tower, q, p) = setup(16, 2, 2);
        let tools = RnsTools::new(&tower, &q, &p);
        let conv_p_to_q = BaseConvTable::new(&tower, &p, &q);
        let p_prod: u128 = p
            .iter()
            .map(|&i| tower.contexts[i].modulus.value() as u128)
            .product();
        let x: u128 = 987654321;
        let xp = x * p_prod; // multiple of P: ModDown is exact
        let full: Vec<usize> = q.iter().chain(p.iter()).copied().collect();
        let mut poly = RnsPoly::zero(&tower, &full, Format::Coeff);
        for (i, &ci) in full.iter().enumerate() {
            let m = tower.contexts[ci].modulus.value() as u128;
            poly.limbs[i][3] = (xp % m) as u64;
        }
        let down = tools.mod_down(&poly, &conv_p_to_q, &tower);
        for (i, &ci) in q.iter().enumerate() {
            let m = tower.contexts[ci].modulus.value() as u128;
            assert_eq!(down.limbs[i][3] as u128, x % m, "limb {i}");
        }
        // Everything else stays zero.
        assert!(down.limbs[0].iter().enumerate().all(|(j, &v)| j == 3 || v == 0));
    }
}
