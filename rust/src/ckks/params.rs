//! CKKS-RNS parameter sets and the shared evaluation context.
//!
//! Mirrors Table I/V of the paper: ring dimension N, multiplicative depth
//! L, the RNS moduli chain Q, the extension chain P (alpha primes) and the
//! key-switching digit count `dnum`.
//!
//! Two width profiles exist:
//! * `Wide` (default, up to 62-bit primes) — high-precision software
//!   substrate used by the functional tests and examples.
//! * `Pe32` (30-bit primes) — the paper's 32-bit FHECore datapath; numbers
//!   flow through the identical Barrett pipeline as the hardware PE and
//!   the L1 Pallas kernel.

use super::poly::Tower;
use super::prime::ntt_primes;
use super::rns::{BaseConvTable, RnsTools};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WidthProfile {
    /// Software substrate: scale-width primes in the 40-60 bit range.
    Wide,
    /// The FHECore PE datapath: all primes in [2^29, 2^30).
    Pe32,
}

#[derive(Debug, Clone)]
pub struct CkksParams {
    /// Ring dimension N (power of two). Paper workloads: 2^16.
    pub n: usize,
    /// Multiplicative depth L: the chain has L+1 primes q_0..q_L.
    pub depth: usize,
    /// log2 of the encoding scale Delta.
    pub scale_bits: u32,
    /// Number of key-switching digits (Table V `dnum`).
    pub dnum: usize,
    pub profile: WidthProfile,
    /// Gaussian noise parameter for fresh encryptions.
    pub sigma: f64,
}

impl CkksParams {
    /// A small, fast parameter set for tests (N=256, depth 3).
    pub fn toy() -> Self {
        Self {
            n: 256,
            depth: 3,
            scale_bits: 40,
            dnum: 2,
            profile: WidthProfile::Wide,
            sigma: 3.2,
        }
    }

    /// Medium set for examples (N=4096, depth 6) — large enough that the
    /// slot count supports the LR/CNN examples, small enough to be quick.
    pub fn medium() -> Self {
        Self {
            n: 4096,
            depth: 6,
            scale_bits: 40,
            dnum: 3,
            profile: WidthProfile::Wide,
            sigma: 3.2,
        }
    }

    pub fn slots(&self) -> usize {
        self.n / 2
    }

    /// Number of extension primes alpha = ceil((L+1)/dnum) (Table I).
    pub fn alpha(&self) -> usize {
        (self.depth + 1).div_ceil(self.dnum)
    }

    /// Bit widths for (q0, scale primes, p primes).
    fn widths(&self) -> (u32, u32, u32) {
        match self.profile {
            WidthProfile::Wide => {
                // q0 carries the message headroom; P primes must dominate
                // the digit product's noise, use the widest lane.
                let q0 = (self.scale_bits + 10).min(60);
                (q0, self.scale_bits, q0 + 1)
            }
            WidthProfile::Pe32 => (30, 30, 30),
        }
    }
}

/// All precomputed state shared by encoder, keys and evaluator.
pub struct CkksContext {
    pub params: CkksParams,
    pub tower: Tower,
    /// Context indices of the Q chain (level l uses q_chain[..=l]).
    pub q_chain: Vec<usize>,
    /// Context indices of the P (extension) chain.
    pub p_chain: Vec<usize>,
    pub tools: RnsTools,
    /// P -> Q conversion used by ModDown after key switching.
    pub conv_p_to_q: BaseConvTable,
    /// The encoding scale Delta.
    pub scale: f64,
}

impl CkksContext {
    pub fn new(params: CkksParams) -> Self {
        let (q0_bits, qi_bits, p_bits) = params.widths();
        let nq = params.depth + 1;
        let alpha = params.alpha();

        // Draw primes per width class, avoiding collisions across classes.
        let mut primes: Vec<u64> = Vec::new();
        if params.profile == WidthProfile::Pe32 {
            // All primes share a width: draw one long descending run.
            primes = ntt_primes(params.n, 30, nq + alpha);
        } else {
            let q0 = ntt_primes(params.n, q0_bits, 1);
            let qi = ntt_primes(params.n, qi_bits, nq - 1);
            let p = ntt_primes(params.n, p_bits, alpha);
            primes.extend(&q0);
            primes.extend(&qi);
            primes.extend(&p);
        }
        let tower = Tower::new(params.n, &primes);
        let q_chain: Vec<usize> = (0..nq).collect();
        let p_chain: Vec<usize> = (nq..nq + alpha).collect();
        let tools = RnsTools::new(&tower, &q_chain, &p_chain);
        let conv_p_to_q = BaseConvTable::new(&tower, &p_chain, &q_chain);
        let scale = 2f64.powi(params.scale_bits as i32);
        Self {
            params,
            tower,
            q_chain,
            p_chain,
            tools,
            conv_p_to_q,
            scale,
        }
    }

    /// Chain for a ciphertext at `level` (levels count down from depth).
    pub fn chain_at(&self, level: usize) -> Vec<usize> {
        assert!(level < self.q_chain.len());
        self.q_chain[..=level].to_vec()
    }

    /// Active chain extended by P (the key-switching working basis).
    pub fn extended_chain_at(&self, level: usize) -> Vec<usize> {
        let mut c = self.chain_at(level);
        c.extend(&self.p_chain);
        c
    }

    pub fn max_level(&self) -> usize {
        self.params.depth
    }

    pub fn modulus_bits_total(&self) -> u32 {
        // logQP of Table V.
        self.tower
            .contexts
            .iter()
            .map(|c| c.modulus.bits())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toy_context_builds() {
        let ctx = CkksContext::new(CkksParams::toy());
        assert_eq!(ctx.q_chain.len(), 4);
        assert_eq!(ctx.p_chain.len(), 2); // ceil(4/2)
        assert_eq!(ctx.chain_at(1), vec![0, 1]);
        assert_eq!(ctx.extended_chain_at(0), vec![0, 4, 5]);
    }

    #[test]
    fn primes_are_distinct_and_ntt_friendly() {
        let ctx = CkksContext::new(CkksParams::toy());
        let primes = ctx.tower.primes();
        let mut sorted = primes.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), primes.len(), "duplicate primes");
        for q in primes {
            assert_eq!((q - 1) % (2 * ctx.params.n as u64), 0);
        }
    }

    #[test]
    fn pe32_profile_uses_30_bit_primes() {
        let params = CkksParams {
            n: 256,
            depth: 2,
            scale_bits: 29,
            dnum: 1,
            profile: WidthProfile::Pe32,
            sigma: 3.2,
        };
        let ctx = CkksContext::new(params);
        for q in ctx.tower.primes() {
            assert!((1 << 29..1 << 30).contains(&q));
        }
    }

    #[test]
    fn alpha_matches_table_v_convention() {
        // Bootstrap row of Table V: L=26, dnum=3 -> alpha = ceil(27/3) = 9.
        let p = CkksParams {
            n: 256,
            depth: 26,
            scale_bits: 40,
            dnum: 3,
            profile: WidthProfile::Wide,
            sigma: 3.2,
        };
        assert_eq!(p.alpha(), 9);
    }
}
