//! Trace-driven A100 timing simulator — the Accel-Sim substitute.
//!
//! Modelling level (matches how Accel-Sim treats Tensor-Core ops — fixed
//! latency units behind scoreboarded warp schedulers):
//!
//! * Each SM has 4 warp schedulers issuing at most one warp-instruction
//!   per cycle each (GTO pick among ready warps of its partition).
//! * Functional units are fixed-latency: a dependent follow-up stalls the
//!   warp by the unit latency (`FHEC.16816` = 44 cycles per SIV-D,
//!   `IMMA.16816` = 64 per Raihan et al., the values SVI-A plugs into
//!   Accel-Sim's `SPECIALIZED_UNIT_3_OP`).
//! * Units also have issue (initiation) intervals per SM, modelling port
//!   counts (4 TCs / 4 FHECores per SM share the register-file ports,
//!   SIV-B) and a DRAM-bandwidth token bucket behind `LDG`.
//! * A kernel is simulated as one **representative resident wave** of
//!   CTAs, cycle by cycle; full-kernel time scales by the wave count
//!   (exact for homogeneous FHE kernels, which these all are).
//!
//! Occupancy comes from the standard limiter math (warp slots, registers,
//! shared memory, CTA slots) — the quantity Fig. 7 reports.

use crate::isa::{KernelClass, KernelLaunch, Opcode, Trace, UnitClass};

/// A100 (GA100) configuration — SII-B of the paper.
#[derive(Debug, Clone)]
pub struct GpuConfig {
    pub sms: u32,
    pub schedulers_per_sm: u32,
    pub max_warps_per_sm: u32,
    pub max_ctas_per_sm: u32,
    pub regfile_per_sm: u32,
    pub smem_per_sm: u32,
    /// Average dynamic clock the paper assumes (SVI-C): 1087.5 MHz.
    pub freq_mhz: f64,
    /// Result latency of an FHEC.16816 (44 = output-stationary 16x8 array,
    /// SIV-D; set to 64 to model the "Enhanced Tensor Core" alternative
    /// of SIV-G).
    pub fhec_latency: u32,
    pub imma_latency: u32,
    pub mem_latency: u32,
    /// Serviced memory bandwidth per SM (bytes/cycle). The paper's
    /// baseline applies MAD's memory-aware optimizations first ("FIDESlib
    /// resolves the memory boundedness ... then we shift our focus to
    /// compute", Fig. 1), so kernels run largely L2-resident: this is
    /// L2-class bandwidth, not raw DRAM.
    pub mem_bytes_per_cycle: f64,
}

impl Default for GpuConfig {
    fn default() -> Self {
        Self {
            sms: 108,
            schedulers_per_sm: 4,
            max_warps_per_sm: 64,
            max_ctas_per_sm: 32,
            regfile_per_sm: 65536,
            smem_per_sm: 164 * 1024,
            freq_mhz: 1087.5,
            fhec_latency: 44,
            imma_latency: 64,
            mem_latency: 350,
            mem_bytes_per_cycle: 32.0,
        }
    }
}

impl GpuConfig {
    /// Result latency per opcode (cycles).
    pub fn latency(&self, op: Opcode) -> u32 {
        match op.unit() {
            UnitClass::Int | UnitClass::Fp => 4,
            UnitClass::Sfu => 16,
            UnitClass::MemGlobal => self.mem_latency,
            UnitClass::MemShared => 25,
            UnitClass::TensorCore => self.imma_latency,
            UnitClass::FheCore => self.fhec_latency,
            UnitClass::Control => 2,
        }
    }

    /// Issue (initiation) interval per unit class per SM partition.
    pub fn initiation(&self, unit: UnitClass) -> u32 {
        match unit {
            UnitClass::Int | UnitClass::Fp => 1,
            UnitClass::Sfu => 4,
            // LDG: 128B per warp access / bandwidth budget per partition.
            UnitClass::MemGlobal => {
                (128.0 / (self.mem_bytes_per_cycle / self.schedulers_per_sm as f64)).ceil() as u32
            }
            UnitClass::MemShared => 2,
            // 4 TCs/FHECores per SM = 1 per scheduler partition; the unit
            // accepts a new MMA every `interval` cycles (pipelined array).
            UnitClass::TensorCore => 8,
            UnitClass::FheCore => 8,
            UnitClass::Control => 1,
        }
    }

    /// CTAs resident per SM for a kernel (occupancy limiters).
    pub fn ctas_per_sm(&self, k: &KernelLaunch) -> u32 {
        let by_warps = self.max_warps_per_sm / k.warps_per_cta.max(1);
        let regs_per_cta = k.regs_per_thread * 32 * k.warps_per_cta;
        let by_regs = if regs_per_cta == 0 {
            u32::MAX
        } else {
            self.regfile_per_sm / regs_per_cta
        };
        let by_smem = if k.smem_per_cta == 0 {
            u32::MAX
        } else {
            self.smem_per_sm / k.smem_per_cta
        };
        by_warps.min(by_regs).min(by_smem).min(self.max_ctas_per_sm).max(1)
    }
}

/// Per-kernel simulation result.
#[derive(Debug, Clone)]
pub struct KernelStats {
    pub name: String,
    pub class: KernelClass,
    pub cycles: u64,
    pub instructions: u64,
    /// Warp-instructions issued per cycle per SM (max = schedulers).
    pub ipc: f64,
    /// Resident warps / warp slots.
    pub occupancy: f64,
    pub waves: u64,
}

/// Whole-trace result.
#[derive(Debug, Clone, Default)]
pub struct TraceStats {
    pub kernels: Vec<KernelStats>,
}

impl TraceStats {
    pub fn total_cycles(&self) -> u64 {
        self.kernels.iter().map(|k| k.cycles).sum()
    }

    pub fn total_instructions(&self) -> u64 {
        self.kernels.iter().map(|k| k.instructions).sum()
    }

    pub fn latency_ms(&self, cfg: &GpuConfig) -> f64 {
        self.total_cycles() as f64 / (cfg.freq_mhz * 1e3)
    }

    pub fn latency_us(&self, cfg: &GpuConfig) -> f64 {
        self.total_cycles() as f64 / cfg.freq_mhz
    }

    /// Cycle-weighted mean IPC (per SM).
    pub fn mean_ipc(&self) -> f64 {
        let cyc = self.total_cycles().max(1) as f64;
        self.kernels.iter().map(|k| k.ipc * k.cycles as f64).sum::<f64>() / cyc
    }

    /// Cycle-weighted mean occupancy.
    pub fn mean_occupancy(&self) -> f64 {
        let cyc = self.total_cycles().max(1) as f64;
        self.kernels
            .iter()
            .map(|k| k.occupancy * k.cycles as f64)
            .sum::<f64>()
            / cyc
    }

    /// Cycles per kernel class (Fig. 1 / Fig. 9 breakdowns).
    pub fn cycles_by_class(&self) -> std::collections::BTreeMap<KernelClass, u64> {
        let mut m = std::collections::BTreeMap::new();
        for k in &self.kernels {
            *m.entry(k.class).or_insert(0) += k.cycles;
        }
        m
    }
}

#[derive(Clone)]
struct WarpState {
    pos: usize,
    rep_left: u32,
    ready: u64,
    done: bool,
}

/// Simulate one kernel on one SM's representative wave; scale by waves.
pub fn simulate_kernel(cfg: &GpuConfig, k: &KernelLaunch) -> KernelStats {
    let ctas_resident = cfg.ctas_per_sm(k).min(k.ctas.max(1) as u32);
    let resident_warps = (ctas_resident * k.warps_per_cta) as usize;
    let total_ctas = k.ctas.max(1);
    let waves = total_ctas
        .div_ceil((ctas_resident as u64) * cfg.sms as u64)
        .max(1);

    let first = &k.template[0];
    let mut warps: Vec<WarpState> = (0..resident_warps)
        .map(|_| WarpState {
            pos: 0,
            rep_left: first.repeat,
            ready: 0,
            done: k.template.is_empty(),
        })
        .collect();

    let sched = cfg.schedulers_per_sm as usize;
    let unit_ids = [
        UnitClass::Int,
        UnitClass::Fp,
        UnitClass::Sfu,
        UnitClass::MemGlobal,
        UnitClass::MemShared,
        UnitClass::TensorCore,
        UnitClass::FheCore,
        UnitClass::Control,
    ];
    let unit_index = |u: UnitClass| unit_ids.iter().position(|&x| x == u).unwrap();
    let mut unit_free = vec![0u64; sched * unit_ids.len()];

    let mut cycle: u64 = 0;
    let mut issued: u64 = 0;
    let mut remaining = resident_warps;
    let mut last_pick = vec![0usize; sched];

    let safety_cap = 2_000_000_000u64;
    while remaining > 0 && cycle < safety_cap {
        let mut next_event = u64::MAX;
        let mut issued_this_cycle = false;
        for s in 0..sched {
            let part: Vec<usize> = (s..warps.len()).step_by(sched).collect();
            if part.is_empty() {
                continue;
            }
            let mut picked = None;
            for off in 0..part.len() {
                let wi = part[(last_pick[s] + off) % part.len()];
                let w = &warps[wi];
                if w.done {
                    continue;
                }
                if w.ready > cycle {
                    next_event = next_event.min(w.ready);
                    continue;
                }
                let instr = k.template[w.pos];
                let ui = s * unit_ids.len() + unit_index(instr.op.unit());
                if unit_free[ui] > cycle {
                    next_event = next_event.min(unit_free[ui]);
                    continue;
                }
                picked = Some((wi, ui));
                break;
            }
            if let Some((wi, ui)) = picked {
                let w = &mut warps[wi];
                let instr = k.template[w.pos];
                issued += 1;
                issued_this_cycle = true;
                unit_free[ui] = cycle + cfg.initiation(instr.op.unit()) as u64;
                let completes = cycle + cfg.latency(instr.op) as u64;
                w.rep_left -= 1;
                let next_dependent = if w.rep_left == 0 {
                    w.pos += 1;
                    if w.pos >= k.template.len() {
                        w.done = true;
                        remaining -= 1;
                        false
                    } else {
                        w.rep_left = k.template[w.pos].repeat;
                        k.template[w.pos].dependent
                    }
                } else {
                    // repeats of a dependent instruction form a serial chain
                    instr.dependent
                };
                if !w.done {
                    w.ready = if next_dependent { completes } else { cycle + 1 };
                    next_event = next_event.min(w.ready);
                }
                last_pick[s] = part.iter().position(|&x| x == wi).unwrap();
            }
        }
        // Advance time: next cycle if anything issued, else jump to the
        // next event (fast-forward through long stalls).
        if issued_this_cycle || next_event == u64::MAX {
            cycle += 1;
        } else {
            cycle = next_event.max(cycle + 1);
        }
    }

    let wave_cycles = cycle.max(1);
    KernelStats {
        name: k.name.clone(),
        class: k.class,
        cycles: wave_cycles * waves,
        instructions: k.dynamic_instructions(),
        ipc: issued as f64 / wave_cycles as f64,
        occupancy: resident_warps as f64 / cfg.max_warps_per_sm as f64,
        waves,
    }
}

/// Simulate a whole trace (kernels serialized, as FIDESlib's stream order).
/// Identical kernel shapes are memoized — FHE traces repeat a handful of
/// shapes thousands of times.
pub fn simulate_trace(cfg: &GpuConfig, t: &Trace) -> TraceStats {
    use std::collections::HashMap;
    let mut memo: HashMap<String, KernelStats> = HashMap::new();
    let mut out = TraceStats::default();
    for k in &t.launches {
        let key = format!("{}:{}:{}", k.name, k.ctas, k.warps_per_cta);
        let stats = memo
            .entry(key)
            .or_insert_with(|| simulate_kernel(cfg, k))
            .clone();
        out.kernels.push(stats);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::{Backend, Compiler, SimParams};
    use crate::isa::Instr;

    fn mini_kernel(op: Opcode, repeat: u32, dependent: bool) -> KernelLaunch {
        // Single resident warp: exposes latency (not throughput) effects.
        KernelLaunch {
            name: "mini".into(),
            class: KernelClass::Other,
            ctas: 1,
            warps_per_cta: 1,
            regs_per_thread: 32,
            smem_per_cta: 0,
            template: vec![
                if dependent {
                    Instr::dep(op, repeat)
                } else {
                    Instr::x(op, repeat)
                },
                Instr::new(Opcode::Exit),
            ],
        }
    }

    #[test]
    fn dependent_chains_serialize_by_latency() {
        let cfg = GpuConfig::default();
        let fast = simulate_kernel(&cfg, &mini_kernel(Opcode::Imma16816, 16, false));
        let slow = simulate_kernel(&cfg, &mini_kernel(Opcode::Imma16816, 16, true));
        assert!(
            slow.cycles > fast.cycles,
            "dependent IMMA chain must be slower: {} vs {}",
            slow.cycles,
            fast.cycles
        );
        assert!(slow.cycles >= 15 * 64, "chain >= 15 latencies: {}", slow.cycles);
    }

    #[test]
    fn fhec_latency_beats_imma_latency() {
        let cfg = GpuConfig::default();
        let imma = simulate_kernel(&cfg, &mini_kernel(Opcode::Imma16816, 16, true));
        let fhec = simulate_kernel(&cfg, &mini_kernel(Opcode::Fhec16816, 16, true));
        assert!(fhec.cycles < imma.cycles, "44 < 64 cycles per issue");
    }

    #[test]
    fn occupancy_limited_by_registers() {
        let cfg = GpuConfig::default();
        let mut k = mini_kernel(Opcode::Imad, 8, false);
        k.ctas = 1024;
        k.warps_per_cta = 8;
        k.regs_per_thread = 255;
        let s = simulate_kernel(&cfg, &k);
        assert!(s.occupancy <= 0.15, "occupancy {} should be tiny", s.occupancy);
    }

    #[test]
    fn waves_scale_cycles_linearly_high_occupancy() {
        let cfg = GpuConfig::default();
        let mut k = mini_kernel(Opcode::Imad, 64, false);
        k.warps_per_cta = 8;
        k.ctas = 108 * 8;
        let one = simulate_kernel(&cfg, &k);
        assert!(one.occupancy > 0.9);
    }

    #[test]
    fn waves_scale_cycles_linearly() {
        let cfg = GpuConfig::default();
        let mut k = mini_kernel(Opcode::Imad, 64, false);
        k.warps_per_cta = 8;
        let one = simulate_kernel(&cfg, &{
            let mut kk = k.clone();
            kk.ctas = 108 * 8;
            kk
        });
        let two = simulate_kernel(&cfg, &{
            let mut kk = k.clone();
            kk.ctas = 2 * 108 * 8;
            kk
        });
        assert_eq!(two.cycles, 2 * one.cycles);
    }

    #[test]
    fn ipc_bounded_by_scheduler_count() {
        let cfg = GpuConfig::default();
        let s = simulate_kernel(&cfg, &mini_kernel(Opcode::Imad, 128, false));
        assert!(s.ipc <= cfg.schedulers_per_sm as f64 + 1e-9);
        assert!(s.ipc > 0.5, "an ALU-only kernel should sustain issue: {}", s.ipc);
    }

    #[test]
    fn primitive_speedups_match_table_vii_shape() {
        // Table VII: Rescale 1.28x, Rotate 1.70x, HEMult 1.77x.
        let cfg = GpuConfig::default();
        let p = SimParams::paper_primitive();
        let speedup = |f: &dyn Fn(&Compiler, &SimParams) -> crate::isa::Trace| {
            let b = simulate_trace(&cfg, &f(&Compiler::new(Backend::A100), &p));
            let h = simulate_trace(&cfg, &f(&Compiler::new(Backend::A100Fhec), &p));
            b.total_cycles() as f64 / h.total_cycles() as f64
        };
        let rescale = speedup(&|c, p| c.rescale(p));
        let rotate = speedup(&|c, p| c.rotate(p));
        let hemult = speedup(&|c, p| c.hemult(p));
        println!("speedups: rescale={rescale:.2} rotate={rotate:.2} hemult={hemult:.2}");
        // Our model's primitive speedups run ~25-60% above the paper's
        // (its isolated primitives are launch-overhead-diluted on real
        // hardware, which a representative-wave model does not charge);
        // the shape requirement is "all primitives speed up, rotate is
        // not below rescale, geomean in the 1.3-2.3 band around 1.57".
        assert!(rescale > 1.05 && rescale < 2.4, "rescale {rescale}");
        assert!(hemult > 1.2 && hemult < 2.6, "hemult {hemult}");
        assert!(rotate > 1.2 && rotate < 2.6, "rotate {rotate}");
        assert!(rotate >= rescale, "keyswitch-heavy rotate must not lose to rescale");
        let geo = (rescale * rotate * hemult).powf(1.0 / 3.0);
        assert!((1.3..2.3).contains(&geo), "primitive speedup geomean {geo:.2} (paper 1.57)");
    }

    #[test]
    fn memoization_returns_same_stats() {
        let cfg = GpuConfig::default();
        let p = SimParams::paper_primitive();
        let t = Compiler::new(Backend::A100).rescale(&p);
        let s1 = simulate_trace(&cfg, &t);
        let s2 = simulate_trace(&cfg, &t);
        assert_eq!(s1.total_cycles(), s2.total_cycles());
    }

    #[test]
    fn enhanced_tensor_core_config_is_slower_than_fhec() {
        // SIV-G: extending TCs inherits the 64-cycle constraint.
        let p = SimParams::paper_primitive();
        let trace = Compiler::new(Backend::A100Fhec).hemult(&p);
        let fhec_cfg = GpuConfig::default();
        let etc_cfg = GpuConfig { fhec_latency: 64, ..GpuConfig::default() };
        let fhec = simulate_trace(&fhec_cfg, &trace);
        let etc = simulate_trace(&etc_cfg, &trace);
        assert!(fhec.total_cycles() <= etc.total_cycles());
    }
}
