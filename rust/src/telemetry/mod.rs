//! End-to-end latency tracing + per-stage time attribution.
//!
//! Three layers, all always-on by default and all reduced to a single
//! relaxed atomic load when disabled (`--trace off` / `FHECORE_TRACE`):
//!
//! 1. **Span tracer** ([`span`]): per-thread ring buffers of
//!    `{span id, parent, request id, tenant fp, stage, t_start, dur}`
//!    events recorded at every seam a request crosses — NTT, base
//!    conversion, ModDown, key-switch, MLT tile sweeps, coordinator
//!    queue wait, the batch former's deadline wait + fused dispatch,
//!    wire encode/decode. Drained over the wire by `fhecore client
//!    trace` and rendered as Chrome trace-event JSON (Perfetto).
//! 2. **Latency histograms** ([`hist`]): log2-ns bucketed p50/p95/p99
//!    per stage and per op kind, queue-wait split from execute, rolled
//!    into `MetricsSnapshot` (wire v7) and summed bucket-wise across
//!    shards by the gateway.
//! 3. **Work accounting** ([`work`]): MLT tile-ops / butterfly
//!    equivalents / Barrett reductions attributed per primitive — the
//!    dynamic-work breakdown the paper's table argues from.

pub mod hist;
pub mod span;
pub mod work;

pub use hist::{merge_buckets, AtomicHist, LatencyHist, BUCKETS};
pub use span::{
    chrome_trace_json, drain_events, enabled, init_from_env, maybe_log_slow, record_exec,
    record_queue_wait, record_span_at, record_span_for, request_scope, set_enabled,
    set_slow_request_ms, slow_request_us, span, span_with, stats_snapshot, RequestScope,
    SpanEvent, SpanGuard, Stage, StatsSnapshot, OP_GROUPS, OP_GROUP_NAMES, STAGE_COUNT,
};
pub use work::{
    add_barrett, add_butterfly_equiv, add_tile_ops, prim_scope, work_delta, work_snapshot,
    PrimScope, Primitive, WorkRow, WorkSnapshot, PRIMITIVES,
};
