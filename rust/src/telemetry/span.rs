//! The lock-light, always-on span tracer.
//!
//! Every instrumented seam (`NttTable::forward_batch`/`inverse_batch`,
//! `BaseConvTable::convert_into`, `ModLinKernel::apply_with`,
//! `KsKey::apply*`/ModDown, coordinator queue wait + execute, the batch
//! former's deadline wait + fused dispatch, wire encode/decode) opens a
//! [`SpanGuard`]; dropping it records one [`SpanEvent`] into a
//! **per-thread ring buffer** and feeds the per-stage histogram
//! aggregates. Cost when enabled: two `Instant::now()` calls plus one
//! push under an uncontended per-thread mutex (that mutex exists only so
//! a trace drain from another thread is safe — the owning thread never
//! blocks on it in steady state). Cost when disabled (`--trace off` /
//! `FHECORE_TRACE=off`): one relaxed atomic load, no clock reads, no
//! allocation — the bit-exactness benches hold the disabled path to
//! noise.
//!
//! Rings are bounded ([`RING_CAPACITY`] events/thread): under overload
//! the oldest events are overwritten and counted in [`dropped_total`],
//! never blocking the hot path. [`drain_events`] (the `TraceReq` RPC)
//! consumes all rings; [`chrome_trace_json`] renders events as Chrome
//! trace-event JSON (`chrome://tracing` / Perfetto: one row per worker
//! thread, spans nested by the parent ids carried in `args`).
//!
//! Request attribution is thread-local: the coordinator/scheduler wraps
//! each request's execution in a [`RequestScope`], so every span a
//! worker records while serving that request carries its `(request id,
//! tenant fingerprint)` — and the scope accumulates a per-stage time
//! breakdown that powers the [`maybe_log_slow`] slow-request log.

use std::cell::Cell;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use super::hist::{AtomicHist, LatencyHist};
use crate::util::json::Json;

/// Environment override honored by [`init_from_env`]:
/// `FHECORE_TRACE=off|0` disables the tracer, `on|1` (or unset) keeps
/// the default-on behavior.
pub const TRACE_ENV: &str = "FHECORE_TRACE";

/// Per-thread ring capacity, in span events (~70 B each).
pub const RING_CAPACITY: usize = 8192;

// ---------------------------------------------------------------------
// Stages
// ---------------------------------------------------------------------

/// Where a span's time was spent. One fixed, wire-stable id per seam —
/// the u8 discriminants ride `SpanEvent` over the wire and index the
/// per-stage histogram/total arrays in `MetricsSnapshot`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Stage {
    /// Batched 4-step NTT (`NttTable::forward_batch`/`inverse_batch`).
    Ntt = 0,
    /// HPS fast base conversion (`BaseConvTable::convert_into`).
    BaseConv = 1,
    /// ModDown after key-switch accumulation.
    ModDown = 2,
    /// A whole key-switch application (hoisted, fused, or per-digit).
    KeySwitch = 3,
    /// One `ModLinKernel::apply` tile sweep (nested under Ntt/BaseConv).
    Mlt = 4,
    /// Coordinator lane queue wait (admission -> batch claim).
    QueueWait = 5,
    /// Batch-former deadline wait (sched admission -> fused claim).
    SchedWait = 6,
    /// One fused multi-tenant dispatch (detail = occupancy).
    FusedDispatch = 7,
    /// Serializing + writing one response frame.
    WireEncode = 8,
    /// Reading + decoding one request frame.
    WireDecode = 9,
    /// Executing one single-op request on a worker.
    Execute = 10,
    /// Executing one whole-program (DAG) request on a worker.
    Program = 11,
}

pub const STAGE_COUNT: usize = 12;

/// Latency-histogram op-kind groups for `MetricsSnapshot::exec_hist`.
pub const OP_GROUPS: usize = 5;

/// Printable names for the exec-histogram groups, index-aligned with
/// `MetricsSnapshot::exec_hist` (see `coordinator::op_group`).
pub const OP_GROUP_NAMES: [&str; OP_GROUPS] = ["rotate", "mul", "elementwise", "linear", "program"];

impl Stage {
    pub const ALL: [Stage; STAGE_COUNT] = [
        Stage::Ntt,
        Stage::BaseConv,
        Stage::ModDown,
        Stage::KeySwitch,
        Stage::Mlt,
        Stage::QueueWait,
        Stage::SchedWait,
        Stage::FusedDispatch,
        Stage::WireEncode,
        Stage::WireDecode,
        Stage::Execute,
        Stage::Program,
    ];

    /// Stable printable id (what the trace JSON and CI greps use).
    pub fn name(self) -> &'static str {
        match self {
            Stage::Ntt => "ntt",
            Stage::BaseConv => "baseconv",
            Stage::ModDown => "moddown",
            Stage::KeySwitch => "keyswitch",
            Stage::Mlt => "mlt",
            Stage::QueueWait => "queue-wait",
            Stage::SchedWait => "sched-wait",
            Stage::FusedDispatch => "fused-dispatch",
            Stage::WireEncode => "wire-encode",
            Stage::WireDecode => "wire-decode",
            Stage::Execute => "execute",
            Stage::Program => "program",
        }
    }

    /// Wire decode of the u8 discriminant.
    pub fn from_u8(v: u8) -> Option<Stage> {
        Stage::ALL.get(v as usize).copied()
    }
}

/// One completed span, as drained from the rings and shipped over the
/// wire (`TraceResp`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpanEvent {
    /// Process-unique span id (monotone).
    pub id: u64,
    /// Enclosing span on the same thread (0 = root).
    pub parent: u64,
    /// Request id from the enclosing [`RequestScope`] (0 = none).
    pub request: u64,
    /// Tenant fingerprint from the enclosing scope (0 = none).
    pub tenant: u64,
    pub stage: Stage,
    /// Start, ns since the process trace epoch.
    pub t_start_ns: u64,
    pub dur_ns: u64,
    /// Stage-specific payload (batch size, fused occupancy, frame
    /// bytes...; 0 = unused).
    pub detail: u64,
    /// Small dense per-thread id (trace rows), assigned on first span.
    pub tid: u32,
}

// ---------------------------------------------------------------------
// Global state
// ---------------------------------------------------------------------

static ENABLED: AtomicBool = AtomicBool::new(true);
static SLOW_REQUEST_US: AtomicU64 = AtomicU64::new(0);
static NEXT_SPAN_ID: AtomicU64 = AtomicU64::new(1);
static NEXT_TID: AtomicU32 = AtomicU32::new(1);
static DROPPED: AtomicU64 = AtomicU64::new(0);

/// Is the tracer recording? One relaxed load — the entire disabled-path
/// cost of an instrumented seam.
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Apply the [`TRACE_ENV`] override (entry points call this once at
/// startup; absent/unrecognized values keep the current setting).
pub fn init_from_env() {
    match std::env::var(TRACE_ENV).ok().as_deref() {
        Some("off") | Some("0") | Some("false") => set_enabled(false),
        Some("on") | Some("1") | Some("true") => set_enabled(true),
        _ => {}
    }
}

/// Slow-request threshold (`--slow-request-ms`); 0 disables the log.
pub fn set_slow_request_ms(ms: u64) {
    SLOW_REQUEST_US.store(ms.saturating_mul(1000), Ordering::Relaxed);
}

pub fn slow_request_us() -> u64 {
    SLOW_REQUEST_US.load(Ordering::Relaxed)
}

/// Events overwritten before any drain could read them (cumulative).
pub fn dropped_total() -> u64 {
    DROPPED.load(Ordering::Relaxed)
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

fn instant_ns(t: Instant) -> u64 {
    t.checked_duration_since(epoch()).map(|d| d.as_nanos() as u64).unwrap_or(0)
}

/// Per-stage aggregates fed on every span drop, plus the queue-wait /
/// per-op-group execute histograms the coordinator records directly.
/// Process-global: the server folds one copy into its (already
/// engine-folded) `MetricsSnapshot`.
#[derive(Default)]
struct GlobalStats {
    stage_hist: [AtomicHist; STAGE_COUNT],
    stage_ns: [AtomicU64; STAGE_COUNT],
    queue_wait: AtomicHist,
    exec: [AtomicHist; OP_GROUPS],
    slow_requests: AtomicU64,
}

fn stats() -> &'static GlobalStats {
    static STATS: OnceLock<GlobalStats> = OnceLock::new();
    STATS.get_or_init(GlobalStats::default)
}

/// Plain-value copy of the process-wide aggregates, shaped to drop
/// straight into `MetricsSnapshot`'s v7 fields.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StatsSnapshot {
    pub queue_wait: LatencyHist,
    pub exec: [LatencyHist; OP_GROUPS],
    pub stage_hist: [LatencyHist; STAGE_COUNT],
    pub stage_ns: [u64; STAGE_COUNT],
    pub slow_requests: u64,
    pub trace_dropped: u64,
}

pub fn stats_snapshot() -> StatsSnapshot {
    let s = stats();
    let mut out = StatsSnapshot {
        queue_wait: s.queue_wait.snapshot(),
        slow_requests: s.slow_requests.load(Ordering::Relaxed),
        trace_dropped: dropped_total(),
        ..StatsSnapshot::default()
    };
    for (o, h) in out.exec.iter_mut().zip(s.exec.iter()) {
        *o = h.snapshot();
    }
    for (o, h) in out.stage_hist.iter_mut().zip(s.stage_hist.iter()) {
        *o = h.snapshot();
    }
    for (o, n) in out.stage_ns.iter_mut().zip(s.stage_ns.iter()) {
        *o = n.load(Ordering::Relaxed);
    }
    out
}

/// Queue-wait sample (both the coordinator lanes and the batch former
/// record here — the wait/execute split the histograms promise).
pub fn record_queue_wait(wait: Duration) {
    if !enabled() {
        return;
    }
    stats().queue_wait.record(wait.as_nanos() as u64);
}

/// Execute-time sample for one op-kind group (`coordinator::op_group`).
pub fn record_exec(group: usize, service: Duration) {
    if !enabled() {
        return;
    }
    stats().exec[group.min(OP_GROUPS - 1)].record(service.as_nanos() as u64);
}

// ---------------------------------------------------------------------
// Per-thread rings
// ---------------------------------------------------------------------

struct Ring {
    buf: Vec<SpanEvent>,
    /// Next overwrite position once the ring is full.
    head: usize,
}

struct ThreadLog {
    ring: Mutex<Ring>,
}

impl ThreadLog {
    fn push(&self, ev: SpanEvent) {
        let mut ring = self.ring.lock().unwrap();
        if ring.buf.len() < RING_CAPACITY {
            ring.buf.push(ev);
        } else {
            let h = ring.head;
            ring.buf[h] = ev;
            ring.head = (h + 1) % RING_CAPACITY;
            DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }
}

fn registry() -> &'static Mutex<Vec<Arc<ThreadLog>>> {
    static REGISTRY: OnceLock<Mutex<Vec<Arc<ThreadLog>>>> = OnceLock::new();
    REGISTRY.get_or_init(|| Mutex::new(Vec::new()))
}

fn register_thread() -> Arc<ThreadLog> {
    let log = Arc::new(ThreadLog {
        ring: Mutex::new(Ring { buf: Vec::with_capacity(64), head: 0 }),
    });
    registry().lock().unwrap().push(log.clone());
    log
}

thread_local! {
    static LOG: Arc<ThreadLog> = register_thread();
    static TID: Cell<u32> = const { Cell::new(0) };
    static PARENT: Cell<u64> = const { Cell::new(0) };
    static REQ_CTX: Cell<(u64, u64)> = const { Cell::new((0, 0)) };
    static BREAKDOWN: Cell<[u64; STAGE_COUNT]> = const { Cell::new([0; STAGE_COUNT]) };
}

fn tid() -> u32 {
    TID.try_with(|t| {
        let v = t.get();
        if v != 0 {
            return v;
        }
        let v = NEXT_TID.fetch_add(1, Ordering::Relaxed);
        t.set(v);
        v
    })
    .unwrap_or(0)
}

fn commit(ev: SpanEvent) {
    let si = ev.stage as usize;
    let s = stats();
    s.stage_hist[si].record(ev.dur_ns);
    s.stage_ns[si].fetch_add(ev.dur_ns, Ordering::Relaxed);
    let _ = BREAKDOWN.try_with(|b| {
        let mut v = b.get();
        v[si] = v[si].saturating_add(ev.dur_ns);
        b.set(v);
    });
    let _ = LOG.try_with(|log| log.push(ev));
}

// ---------------------------------------------------------------------
// Spans
// ---------------------------------------------------------------------

struct ActiveSpan {
    id: u64,
    parent: u64,
    stage: Stage,
    t_start_ns: u64,
    detail: u64,
}

/// RAII span: created at a seam entry, records on drop. When the tracer
/// is disabled this is a `None` and both ends are free of clock reads.
pub struct SpanGuard {
    active: Option<ActiveSpan>,
}

/// Open a span for `stage` on this thread.
pub fn span(stage: Stage) -> SpanGuard {
    span_with(stage, 0)
}

/// [`span`] with a stage-specific detail payload (batch size, bytes...).
pub fn span_with(stage: Stage, detail: u64) -> SpanGuard {
    if !enabled() {
        return SpanGuard { active: None };
    }
    let id = NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed);
    let Ok(parent) = PARENT.try_with(|p| p.replace(id)) else {
        return SpanGuard { active: None };
    };
    SpanGuard {
        active: Some(ActiveSpan { id, parent, stage, t_start_ns: now_ns(), detail }),
    }
}

impl SpanGuard {
    /// Update the detail payload before the span closes (e.g. a byte
    /// count only known mid-seam).
    pub fn set_detail(&mut self, detail: u64) {
        if let Some(a) = &mut self.active {
            a.detail = detail;
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(a) = self.active.take() else { return };
        let dur_ns = now_ns().saturating_sub(a.t_start_ns);
        let _ = PARENT.try_with(|p| p.set(a.parent));
        let (request, tenant) = REQ_CTX.try_with(|c| c.get()).unwrap_or((0, 0));
        commit(SpanEvent {
            id: a.id,
            parent: a.parent,
            request,
            tenant,
            stage: a.stage,
            t_start_ns: a.t_start_ns,
            dur_ns,
            detail: a.detail,
            tid: tid(),
        });
    }
}

/// Record a span whose interval already elapsed (queue/deadline waits:
/// the wait is only known once the work is claimed, so the span is
/// emitted retroactively from the admission timestamp). Uses the
/// calling thread's request context.
pub fn record_span_at(stage: Stage, start: Instant, end: Instant, detail: u64) {
    let (request, tenant) = REQ_CTX.try_with(|c| c.get()).unwrap_or((0, 0));
    record_span_for(stage, start, end, detail, request, tenant);
}

/// [`record_span_at`] with explicit request/tenant attribution (the
/// fused dispatcher emits one wait span per member, each under a
/// different request id, from a single thread).
pub fn record_span_for(
    stage: Stage,
    start: Instant,
    end: Instant,
    detail: u64,
    request: u64,
    tenant: u64,
) {
    if !enabled() {
        return;
    }
    let t_start_ns = instant_ns(start);
    let dur_ns = end.checked_duration_since(start).map(|d| d.as_nanos() as u64).unwrap_or(0);
    commit(SpanEvent {
        id: NEXT_SPAN_ID.fetch_add(1, Ordering::Relaxed),
        parent: PARENT.try_with(|p| p.get()).unwrap_or(0),
        request,
        tenant,
        stage,
        t_start_ns,
        dur_ns,
        detail,
        tid: tid(),
    });
}

// ---------------------------------------------------------------------
// Request attribution + slow-request log
// ---------------------------------------------------------------------

/// RAII request context: while alive, every span this thread records
/// carries `(request, tenant)`, and per-stage time accumulates into a
/// fresh breakdown readable via [`RequestScope::breakdown`]. Nesting
/// restores the outer context on drop.
pub struct RequestScope {
    prev_ctx: (u64, u64),
    prev_breakdown: [u64; STAGE_COUNT],
}

pub fn request_scope(request: u64, tenant: u64) -> RequestScope {
    let prev_ctx = REQ_CTX.try_with(|c| c.replace((request, tenant))).unwrap_or((0, 0));
    let prev_breakdown =
        BREAKDOWN.try_with(|b| b.replace([0; STAGE_COUNT])).unwrap_or([0; STAGE_COUNT]);
    RequestScope { prev_ctx, prev_breakdown }
}

impl RequestScope {
    /// Per-stage ns accumulated on this thread since the scope opened.
    pub fn breakdown(&self) -> [u64; STAGE_COUNT] {
        BREAKDOWN.try_with(|b| b.get()).unwrap_or([0; STAGE_COUNT])
    }
}

impl Drop for RequestScope {
    fn drop(&mut self) {
        let _ = REQ_CTX.try_with(|c| c.set(self.prev_ctx));
        let _ = BREAKDOWN.try_with(|b| b.set(self.prev_breakdown));
    }
}

/// If `total` exceeds the `--slow-request-ms` threshold, emit ONE
/// structured stderr line — tenant fingerprint, op, batch occupancy,
/// and the non-zero per-stage breakdown — and count it. No-op while the
/// threshold is 0 (the default).
pub fn maybe_log_slow(
    request: u64,
    tenant: u64,
    op: &str,
    occupancy: usize,
    total: Duration,
    breakdown: &[u64; STAGE_COUNT],
) {
    let thr_us = SLOW_REQUEST_US.load(Ordering::Relaxed);
    if thr_us == 0 || total.as_micros() < thr_us as u128 {
        return;
    }
    stats().slow_requests.fetch_add(1, Ordering::Relaxed);
    let mut stages = String::new();
    for (i, &ns) in breakdown.iter().enumerate() {
        if ns == 0 {
            continue;
        }
        use std::fmt::Write as _;
        let _ = write!(stages, " {}={:.3}ms", Stage::ALL[i].name(), ns as f64 / 1e6);
    }
    eprintln!(
        "fhecore-slow: request={request} tenant={tenant:#018x} op={op} batch={occupancy} \
         total_ms={:.3} stages{stages}",
        total.as_secs_f64() * 1e3
    );
}

/// Slow requests logged so far (for `MetricsSnapshot`).
pub fn slow_requests_total() -> u64 {
    stats().slow_requests.load(Ordering::Relaxed)
}

// ---------------------------------------------------------------------
// Drain + Chrome trace export
// ---------------------------------------------------------------------

/// Consume every thread's ring: all events recorded since the last
/// drain (sorted by start time), plus the cumulative overwrite count.
pub fn drain_events() -> (Vec<SpanEvent>, u64) {
    let logs: Vec<Arc<ThreadLog>> = registry().lock().unwrap().clone();
    let mut out = Vec::new();
    for log in logs {
        let mut ring = log.ring.lock().unwrap();
        out.append(&mut ring.buf);
        ring.head = 0;
    }
    out.sort_by_key(|e| (e.t_start_ns, e.id));
    (out, dropped_total())
}

/// Render span events as Chrome trace-event JSON (the "X" complete-event
/// form): load the output in `chrome://tracing` or
/// <https://ui.perfetto.dev> to see one lane per worker thread with
/// nested NTT/BaseConv/ModDown spans inside each key-switch. Request id
/// and tenant fingerprint ride in `args` for grouping/filtering.
pub fn chrome_trace_json(events: &[SpanEvent]) -> Json {
    use std::collections::BTreeMap;
    let obj = |pairs: Vec<(&str, Json)>| {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>())
    };
    let evs: Vec<Json> = events
        .iter()
        .map(|e| {
            obj(vec![
                ("name", Json::Str(e.stage.name().to_string())),
                ("cat", Json::Str("fhecore".to_string())),
                ("ph", Json::Str("X".to_string())),
                ("ts", Json::Num(e.t_start_ns as f64 / 1e3)),
                ("dur", Json::Num(e.dur_ns as f64 / 1e3)),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(e.tid as f64)),
                (
                    "args",
                    obj(vec![
                        ("span", Json::Num(e.id as f64)),
                        ("parent", Json::Num(e.parent as f64)),
                        ("request", Json::Num(e.request as f64)),
                        ("tenant", Json::Str(format!("{:#018x}", e.tenant))),
                        ("detail", Json::Num(e.detail as f64)),
                    ]),
                ),
            ])
        })
        .collect();
    obj(vec![
        ("traceEvents", Json::Arr(evs)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The tracer is process-global; tests that flip it or drain rings
    /// serialize here (and restore the enabled default on exit).
    fn serialized() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        match GATE.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        }
    }

    #[test]
    fn spans_nest_and_carry_request_context() {
        let _gate = serialized();
        set_enabled(true);
        let _ = drain_events();
        {
            let _scope = request_scope(77, 0xFEED);
            let outer = span(Stage::KeySwitch);
            {
                let _inner = span_with(Stage::Ntt, 4);
            }
            drop(outer);
        }
        let (events, _) = drain_events();
        let ntt: Vec<_> = events.iter().filter(|e| e.stage == Stage::Ntt).collect();
        let ks: Vec<_> = events.iter().filter(|e| e.stage == Stage::KeySwitch).collect();
        assert_eq!(ntt.len(), 1);
        assert_eq!(ks.len(), 1);
        assert_eq!(ntt[0].parent, ks[0].id, "inner span must point at the outer");
        assert_eq!(ks[0].parent, 0, "outer span is a root");
        assert_eq!(ntt[0].request, 77);
        assert_eq!(ntt[0].tenant, 0xFEED);
        assert_eq!(ntt[0].detail, 4);
        assert!(ks[0].dur_ns >= ntt[0].dur_ns, "outer covers inner");
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let _gate = serialized();
        let _ = drain_events();
        set_enabled(false);
        {
            let _s = span(Stage::BaseConv);
            record_span_at(Stage::QueueWait, Instant::now(), Instant::now(), 0);
            record_queue_wait(Duration::from_micros(5));
        }
        set_enabled(true);
        let (events, _) = drain_events();
        assert!(events.is_empty(), "disabled tracer must record nothing: {events:?}");
    }

    #[test]
    fn ring_overflow_drops_oldest_and_counts() {
        let _gate = serialized();
        set_enabled(true);
        let _ = drain_events();
        let before = dropped_total();
        for _ in 0..RING_CAPACITY + 10 {
            let _s = span(Stage::Mlt);
        }
        let (events, dropped) = drain_events();
        assert_eq!(events.len(), RING_CAPACITY);
        assert!(dropped >= before + 10, "overwrites must be counted");
    }

    #[test]
    fn request_scope_breakdown_accumulates_and_restores() {
        let _gate = serialized();
        set_enabled(true);
        let outer = request_scope(1, 1);
        {
            let inner = request_scope(2, 2);
            {
                let _s = span(Stage::ModDown);
            }
            assert!(inner.breakdown()[Stage::ModDown as usize] > 0);
        }
        // The inner scope's time must not leak into the restored outer
        // breakdown.
        assert_eq!(outer.breakdown()[Stage::ModDown as usize], 0);
        let _ = drain_events();
    }

    #[test]
    fn chrome_json_shape_is_valid_and_reparses() {
        let events = [SpanEvent {
            id: 9,
            parent: 3,
            request: 12,
            tenant: 0xABC,
            stage: Stage::FusedDispatch,
            t_start_ns: 2_500,
            dur_ns: 1_000,
            detail: 7,
            tid: 2,
        }];
        let json = chrome_trace_json(&events);
        let printed = json.to_string_pretty();
        let back = Json::parse(&printed).expect("chrome trace JSON must parse");
        let evs = back.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 1);
        assert_eq!(evs[0].get("name").unwrap().as_str(), Some("fused-dispatch"));
        assert_eq!(evs[0].get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(evs[0].get("ts").unwrap().as_f64(), Some(2.5));
        assert_eq!(evs[0].get("dur").unwrap().as_f64(), Some(1.0));
        let args = evs[0].get("args").unwrap();
        assert_eq!(args.get("detail").unwrap().as_f64(), Some(7.0));
        assert_eq!(args.get("tenant").unwrap().as_str(), Some("0x0000000000000abc"));
    }

    #[test]
    fn stage_u8_roundtrip_is_total() {
        for (i, s) in Stage::ALL.iter().enumerate() {
            assert_eq!(*s as usize, i, "ALL must be discriminant-ordered");
            assert_eq!(Stage::from_u8(i as u8), Some(*s));
        }
        assert_eq!(Stage::from_u8(STAGE_COUNT as u8), None);
        // Names are unique (trace consumers key on them).
        let mut names: Vec<_> = Stage::ALL.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), STAGE_COUNT);
    }

    #[test]
    fn slow_request_log_counts_only_past_threshold() {
        let _gate = serialized();
        let before = slow_requests_total();
        set_slow_request_ms(10);
        let bd = [0u64; STAGE_COUNT];
        maybe_log_slow(1, 2, "Mul", 1, Duration::from_millis(5), &bd);
        assert_eq!(slow_requests_total(), before, "below threshold must not log");
        maybe_log_slow(1, 2, "Mul", 1, Duration::from_millis(25), &bd);
        assert_eq!(slow_requests_total(), before + 1);
        set_slow_request_ms(0);
        maybe_log_slow(1, 2, "Mul", 1, Duration::from_secs(60), &bd);
        assert_eq!(slow_requests_total(), before + 1, "0 disables the log");
    }
}
