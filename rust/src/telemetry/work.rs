//! Dynamic work accounting per FHE primitive.
//!
//! The paper's argument is a *work breakdown*: NTT and base conversion
//! dominate CKKS dynamic instructions, which is why one shared MLT unit
//! wins. This module counts the three machine-level work units our MLT
//! formulation actually executes — **tile-ops** (one `sum_k w[i][k] *
//! x[k][j] mod q` MLT output element), **butterfly-equivalents** (the
//! classical `(n/2) log2 n` per transformed polynomial, so the NTT
//! numbers are comparable to the paper's table even though we execute
//! them as MLT tiles), and **Barrett reductions** (one exact reduction
//! per output element under the lazy-reduction backends) — attributed
//! to the *primitive* that triggered them.
//!
//! Attribution is a thread-local [`Primitive`] set by the enclosing
//! seam via [`prim_scope`]: `NttTable::dft4_batch` brackets itself with
//! `Ntt`, `BaseConvTable::convert_into` with `BaseConv`, and so on —
//! then the `ModLinKernel` hot path calls [`add_tile_ops`] /
//! [`add_barrett`] without knowing who its caller is. Counters are
//! global relaxed atomics; the snapshot rides `MetricsSnapshot` (wire
//! v7) and the telemetry bench prints the breakdown table.

use std::cell::Cell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use super::span::enabled;

/// Which primitive triggered the work. `Other` (0) is the default when
/// no scope is open (e.g. a bare `ModLinKernel::apply` from a test).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
#[repr(u8)]
pub enum Primitive {
    Other = 0,
    Ntt = 1,
    BaseConv = 2,
    ModDown = 3,
    KeySwitch = 4,
}

pub const PRIMITIVES: usize = 5;

impl Primitive {
    pub const ALL: [Primitive; PRIMITIVES] = [
        Primitive::Other,
        Primitive::Ntt,
        Primitive::BaseConv,
        Primitive::ModDown,
        Primitive::KeySwitch,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Primitive::Other => "other",
            Primitive::Ntt => "ntt",
            Primitive::BaseConv => "baseconv",
            Primitive::ModDown => "moddown",
            Primitive::KeySwitch => "keyswitch",
        }
    }

    pub fn from_u8(v: u8) -> Option<Primitive> {
        Primitive::ALL.get(v as usize).copied()
    }
}

#[derive(Default)]
struct Row {
    calls: AtomicU64,
    tile_ops: AtomicU64,
    butterflies: AtomicU64,
    barrett: AtomicU64,
}

#[derive(Default)]
struct Counters {
    rows: [Row; PRIMITIVES],
}

fn counters() -> &'static Counters {
    static COUNTERS: OnceLock<Counters> = OnceLock::new();
    COUNTERS.get_or_init(Counters::default)
}

thread_local! {
    static CURRENT: Cell<u8> = const { Cell::new(0) };
}

fn current() -> usize {
    CURRENT.try_with(|c| c.get() as usize).unwrap_or(0).min(PRIMITIVES - 1)
}

/// RAII attribution scope: work counted while alive is charged to
/// `prim`. Nested scopes charge the innermost primitive (a base
/// conversion inside a key-switch counts as base conversion — matching
/// how the paper's table splits its rows).
pub struct PrimScope {
    prev: u8,
}

pub fn prim_scope(prim: Primitive) -> PrimScope {
    let prev = CURRENT.try_with(|c| c.replace(prim as u8)).unwrap_or(0);
    if enabled() {
        counters().rows[prim as usize].calls.fetch_add(1, Ordering::Relaxed);
    }
    PrimScope { prev }
}

impl Drop for PrimScope {
    fn drop(&mut self) {
        let _ = CURRENT.try_with(|c| c.set(self.prev));
    }
}

/// Count MLT output elements (`rows * n * k` per apply).
pub fn add_tile_ops(n: u64) {
    if enabled() {
        counters().rows[current()].tile_ops.fetch_add(n, Ordering::Relaxed);
    }
}

/// Count classical butterfly-equivalents (`b * (n/2) * log2 n` per NTT
/// batch) — kept separate from tile-ops so the MLT formulation stays
/// comparable with butterfly-counting hardware papers.
pub fn add_butterfly_equiv(n: u64) {
    if enabled() {
        counters().rows[current()].butterflies.fetch_add(n, Ordering::Relaxed);
    }
}

/// Count exact Barrett reductions (one per MLT output element under the
/// lazy-reduction backends).
pub fn add_barrett(n: u64) {
    if enabled() {
        counters().rows[current()].barrett.fetch_add(n, Ordering::Relaxed);
    }
}

/// One primitive's row in the dynamic-work breakdown.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkRow {
    pub calls: u64,
    pub tile_ops: u64,
    pub butterflies: u64,
    pub barrett: u64,
}

/// The full breakdown, index-aligned with [`Primitive::ALL`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkSnapshot {
    pub rows: [WorkRow; PRIMITIVES],
}

impl WorkSnapshot {
    pub fn total_tile_ops(&self) -> u64 {
        self.rows.iter().fold(0u64, |a, r| a.saturating_add(r.tile_ops))
    }

    /// Fraction of total tile-ops charged to `prim` (0.0 when idle).
    pub fn share(&self, prim: Primitive) -> f64 {
        let total = self.total_tile_ops();
        if total == 0 {
            0.0
        } else {
            self.rows[prim as usize].tile_ops as f64 / total as f64
        }
    }
}

pub fn work_snapshot() -> WorkSnapshot {
    let c = counters();
    let mut out = WorkSnapshot::default();
    for (o, r) in out.rows.iter_mut().zip(c.rows.iter()) {
        *o = WorkRow {
            calls: r.calls.load(Ordering::Relaxed),
            tile_ops: r.tile_ops.load(Ordering::Relaxed),
            butterflies: r.butterflies.load(Ordering::Relaxed),
            barrett: r.barrett.load(Ordering::Relaxed),
        };
    }
    out
}

/// Difference of two snapshots (for bracketing one workload).
pub fn work_delta(after: &WorkSnapshot, before: &WorkSnapshot) -> WorkSnapshot {
    let mut out = WorkSnapshot::default();
    for i in 0..PRIMITIVES {
        out.rows[i] = WorkRow {
            calls: after.rows[i].calls.saturating_sub(before.rows[i].calls),
            tile_ops: after.rows[i].tile_ops.saturating_sub(before.rows[i].tile_ops),
            butterflies: after.rows[i].butterflies.saturating_sub(before.rows[i].butterflies),
            barrett: after.rows[i].barrett.saturating_sub(before.rows[i].barrett),
        };
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::span::set_enabled;
    use std::sync::Mutex;

    fn serialized() -> std::sync::MutexGuard<'static, ()> {
        static GATE: Mutex<()> = Mutex::new(());
        match GATE.lock() {
            Ok(g) => g,
            Err(poison) => poison.into_inner(),
        }
    }

    #[test]
    fn scopes_attribute_to_innermost_primitive() {
        let _gate = serialized();
        set_enabled(true);
        let before = work_snapshot();
        {
            let _ks = prim_scope(Primitive::KeySwitch);
            add_tile_ops(10);
            {
                let _bc = prim_scope(Primitive::BaseConv);
                add_tile_ops(100);
                add_barrett(5);
            }
            add_tile_ops(1); // back to keyswitch after inner drop
        }
        add_butterfly_equiv(7); // no scope -> Other
        let d = work_delta(&work_snapshot(), &before);
        assert_eq!(d.rows[Primitive::KeySwitch as usize].tile_ops, 11);
        assert_eq!(d.rows[Primitive::KeySwitch as usize].calls, 1);
        assert_eq!(d.rows[Primitive::BaseConv as usize].tile_ops, 100);
        assert_eq!(d.rows[Primitive::BaseConv as usize].barrett, 5);
        assert_eq!(d.rows[Primitive::Other as usize].butterflies, 7);
    }

    #[test]
    fn disabled_tracer_counts_nothing() {
        let _gate = serialized();
        set_enabled(false);
        let before = work_snapshot();
        {
            let _s = prim_scope(Primitive::Ntt);
            add_tile_ops(1000);
            add_butterfly_equiv(1000);
            add_barrett(1000);
        }
        set_enabled(true);
        let d = work_delta(&work_snapshot(), &before);
        assert_eq!(d, WorkSnapshot::default());
    }

    #[test]
    fn shares_sum_to_one_when_busy() {
        let _gate = serialized();
        set_enabled(true);
        let before = work_snapshot();
        {
            let _s = prim_scope(Primitive::Ntt);
            add_tile_ops(300);
        }
        {
            let _s = prim_scope(Primitive::BaseConv);
            add_tile_ops(100);
        }
        let d = work_delta(&work_snapshot(), &before);
        assert_eq!(d.total_tile_ops(), 400);
        let sum: f64 = Primitive::ALL.iter().map(|&p| d.share(p)).sum();
        assert!((sum - 1.0).abs() < 1e-12);
        assert!((d.share(Primitive::Ntt) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn primitive_u8_roundtrip() {
        for (i, p) in Primitive::ALL.iter().enumerate() {
            assert_eq!(*p as usize, i);
            assert_eq!(Primitive::from_u8(i as u8), Some(*p));
        }
        assert_eq!(Primitive::from_u8(PRIMITIVES as u8), None);
    }
}
