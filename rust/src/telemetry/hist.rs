//! Log-bucketed latency histograms.
//!
//! One histogram is 32 power-of-two nanosecond buckets: bucket `i`
//! counts durations in `[2^i, 2^(i+1))` ns (bucket 0 also absorbs 0 and
//! 1 ns, bucket 31 is open-ended at ~2.1 s+). 32 buckets cover sub-ns
//! to multi-second latencies, keep every histogram a fixed 256-byte
//! `Copy` value that rides `MetricsSnapshot` over the wire, and merge
//! across shards with one saturating add per bucket — no rebinning,
//! because every producer uses the same bucket edges.
//!
//! [`merge_buckets`] is the single bucket-wise merge helper shared by
//! every fixed-bucket counter in the tree: the latency histograms here
//! *and* the batch-former occupancy histogram in
//! `MetricsSnapshot::absorb` (which previously hand-rolled its own
//! loop).
//!
//! Quantiles ([`LatencyHist::quantile_ns`]) are bucket-resolution
//! approximations: the reported value is the inclusive upper edge of
//! the bucket containing the requested rank, i.e. a conservative
//! (never under-reported beyond bucket width) latency estimate.

use std::sync::atomic::{AtomicU64, Ordering};

/// Bucket count. Exactly 32 — large enough for 1 ns..4 s at log2
/// resolution, and the largest array length for which `[u64; N]` still
/// derives `Default`.
pub const BUCKETS: usize = 32;

/// Saturating element-wise accumulate of `src` into `dst` — the one
/// bucket-wise merge every histogram-shaped counter shares (latency
/// histograms here, the fused-occupancy histogram in the coordinator).
/// Length mismatches merge the common prefix; saturation (not wrap) on
/// overflow keeps long-lived gateway aggregations monotone.
pub fn merge_buckets(dst: &mut [u64], src: &[u64]) {
    for (d, s) in dst.iter_mut().zip(src.iter()) {
        *d = d.saturating_add(*s);
    }
}

/// The bucket a duration of `ns` nanoseconds lands in: `floor(log2 ns)`
/// clamped to `[0, BUCKETS)`.
pub fn bucket_index(ns: u64) -> usize {
    if ns < 2 {
        0
    } else {
        ((63 - ns.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive lower edge of bucket `i` in ns.
pub fn bucket_lower_ns(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << i
    }
}

/// Inclusive upper edge of bucket `i` in ns (the value quantiles
/// report). The last bucket is open-ended; its nominal edge is
/// `2^BUCKETS - 1`.
pub fn bucket_upper_ns(i: usize) -> u64 {
    (1u64 << (i + 1).min(63)) - 1
}

/// A plain (non-atomic) log-bucketed histogram — the snapshot/wire
/// form. `Copy` so it can ride `MetricsSnapshot` by value.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LatencyHist {
    pub buckets: [u64; BUCKETS],
}

impl LatencyHist {
    /// Count one duration.
    pub fn record(&mut self, ns: u64) {
        let i = bucket_index(ns);
        self.buckets[i] = self.buckets[i].saturating_add(1);
    }

    /// Total recorded samples (saturating).
    pub fn count(&self) -> u64 {
        self.buckets.iter().fold(0u64, |a, &b| a.saturating_add(b))
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.iter().all(|&b| b == 0)
    }

    /// Bucket-wise merge of `other` into `self` (shared helper).
    pub fn merge(&mut self, other: &LatencyHist) {
        merge_buckets(&mut self.buckets, &other.buckets);
    }

    /// Approximate `q`-quantile in ns (`q` in `(0, 1]`): the upper edge
    /// of the bucket containing rank `ceil(q * count)`. Empty
    /// histograms report 0.
    pub fn quantile_ns(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let rank = ((q * total as f64).ceil() as u64).clamp(1, total);
        let mut seen = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            seen = seen.saturating_add(c);
            if seen >= rank {
                return bucket_upper_ns(i);
            }
        }
        bucket_upper_ns(BUCKETS - 1)
    }

    /// `p50/p95/p99` in microseconds — the operator-facing summary line.
    pub fn summary_us(&self) -> (f64, f64, f64) {
        (
            self.quantile_ns(0.50) as f64 / 1e3,
            self.quantile_ns(0.95) as f64 / 1e3,
            self.quantile_ns(0.99) as f64 / 1e3,
        )
    }
}

/// The live (recording) form: one relaxed `fetch_add` per sample, no
/// locks — safe to hit from every worker thread concurrently.
#[derive(Debug, Default)]
pub struct AtomicHist {
    buckets: [AtomicU64; BUCKETS],
}

impl AtomicHist {
    pub fn record(&self, ns: u64) {
        self.buckets[bucket_index(ns)].fetch_add(1, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> LatencyHist {
        let mut out = LatencyHist::default();
        for (o, b) in out.buckets.iter_mut().zip(self.buckets.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::check;

    #[test]
    fn bucket_edges_are_consistent() {
        for i in 0..BUCKETS {
            assert_eq!(bucket_index(bucket_lower_ns(i)), i, "lower edge of {i}");
            if i < BUCKETS - 1 {
                assert_eq!(bucket_index(bucket_upper_ns(i)), i, "upper edge of {i}");
                assert_eq!(bucket_upper_ns(i) + 1, bucket_lower_ns(i + 1));
            }
        }
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(u64::MAX), BUCKETS - 1);
    }

    #[test]
    fn prop_every_sample_lands_in_its_bucket_range() {
        check("sample-in-range", 500, |rng| {
            let ns = rng.next_u64() >> (rng.below(64) as u32);
            let i = bucket_index(ns);
            assert!(ns >= bucket_lower_ns(i), "ns={ns} below bucket {i}");
            if i < BUCKETS - 1 {
                assert!(ns <= bucket_upper_ns(i), "ns={ns} above bucket {i}");
            }
        });
    }

    #[test]
    fn prop_merge_is_bucketwise_saturating_add() {
        check("merge-bucketwise", 300, |rng| {
            let mut a = LatencyHist::default();
            let mut b = LatencyHist::default();
            for x in a.buckets.iter_mut() {
                // Mix huge values in so saturation actually triggers.
                *x = if rng.below(8) == 0 { u64::MAX - rng.below(3) } else { rng.below(1 << 40) };
            }
            for x in b.buckets.iter_mut() {
                *x = if rng.below(8) == 0 { u64::MAX - rng.below(3) } else { rng.below(1 << 40) };
            }
            let mut merged = a;
            merged.merge(&b);
            for i in 0..BUCKETS {
                assert_eq!(
                    merged.buckets[i],
                    a.buckets[i].saturating_add(b.buckets[i]),
                    "bucket {i}"
                );
            }
            // Merge must be commutative bucket-wise.
            let mut flipped = b;
            flipped.merge(&a);
            assert_eq!(flipped, merged);
        });
    }

    #[test]
    fn prop_merged_count_matches_recording_into_one() {
        check("merge-equals-single-recorder", 200, |rng| {
            let mut a = LatencyHist::default();
            let mut b = LatencyHist::default();
            let mut all = LatencyHist::default();
            for _ in 0..rng.below(200) {
                let ns = rng.next_u64() >> (rng.below(64) as u32);
                if rng.below(2) == 0 {
                    a.record(ns);
                } else {
                    b.record(ns);
                }
                all.record(ns);
            }
            a.merge(&b);
            assert_eq!(a, all, "split recording then merge != single recorder");
        });
    }

    #[test]
    fn prop_quantiles_are_monotone_and_bracket_samples() {
        check("quantile-monotone", 200, |rng| {
            let mut h = LatencyHist::default();
            let n = 1 + rng.below(100);
            let mut max_ns = 0u64;
            for _ in 0..n {
                let ns = rng.next_u64() >> (rng.below(64) as u32);
                max_ns = max_ns.max(ns);
                h.record(ns);
            }
            let (p50, p95, p99) = (h.quantile_ns(0.5), h.quantile_ns(0.95), h.quantile_ns(0.99));
            assert!(p50 <= p95 && p95 <= p99, "quantiles not monotone");
            // p100 upper edge must bracket the true maximum (within the
            // open-ended last bucket).
            let p100 = h.quantile_ns(1.0);
            if bucket_index(max_ns) < BUCKETS - 1 {
                assert!(p100 >= max_ns, "p100 {p100} < max sample {max_ns}");
            }
        });
    }

    #[test]
    fn empty_histogram_reports_zero_quantiles() {
        let h = LatencyHist::default();
        assert_eq!(h.count(), 0);
        assert!(h.is_empty());
        assert_eq!(h.quantile_ns(0.5), 0);
        assert_eq!(h.quantile_ns(0.99), 0);
        assert_eq!(h.summary_us(), (0.0, 0.0, 0.0));
    }

    #[test]
    fn single_sample_dominates_every_quantile() {
        let mut h = LatencyHist::default();
        h.record(1_500); // bucket 10: [1024, 2048)
        for q in [0.01, 0.5, 0.99, 1.0] {
            assert_eq!(h.quantile_ns(q), 2047, "q={q}");
        }
    }

    #[test]
    fn atomic_hist_snapshot_matches_plain_recording() {
        let ah = AtomicHist::default();
        let mut h = LatencyHist::default();
        for ns in [0u64, 1, 7, 1000, 123_456, u64::MAX] {
            ah.record(ns);
            h.record(ns);
        }
        assert_eq!(ah.snapshot(), h);
    }

    #[test]
    fn merge_buckets_handles_length_mismatch() {
        let mut dst = [1u64, 2, 3];
        merge_buckets(&mut dst, &[10, 20]);
        assert_eq!(dst, [11, 22, 3]);
        let mut dst2 = [u64::MAX, 1];
        merge_buckets(&mut dst2, &[5, 5, 5]);
        assert_eq!(dst2, [u64::MAX, 6]);
    }
}
