//! L3 coordinator: the encrypted-inference serving loop.
//!
//! This is the deployment shell around the paper's system: clients submit
//! ciphertexts, the coordinator batches them, workers execute the
//! homomorphic compute through the CKKS substrate, and every batch is
//! *dually dispatched* — functionally (real ciphertext math, optionally
//! through the PJRT FHECore artifacts) and to the timing model (gpusim),
//! so each response carries both the real result and the simulated
//! A100/A100+FHECore latency for that batch's op mix.
//!
//! **Workers hold no secret material.** They are constructed from an
//! `Arc<Evaluator>` whose only key state is the shared public
//! `Arc<EvalKeySet>`; an op whose key the client never declared comes
//! back as a typed [`MissingKey`] in the response instead of being
//! silently derived server-side.
//!
//! **Per-op routing.** Every [`OpKind`] is classified by the hardware it
//! exercises ([`OpClass`]): key-switch-heavy ops (mul, rotate, conjugate,
//! the linear transforms) are *FHEC-class* — on the paper's accelerator
//! they occupy the modified Tensor Cores — while add/rescale-only ops are
//! *CUDA-class* elementwise work. The two classes run on separate queues
//! with their own worker shares, so a burst of cheap adds can never starve
//! behind a deep key-switch batch (and vice versa). Queue depths per lane
//! are exported through [`Coordinator::snapshot`] / the wire `Metrics`
//! RPC.
//!
//! Built on std threads + Condvar-signalled batch queues (tokio is not
//! vendored in this offline build; the architecture is the same): submit
//! is *bounded* — beyond `ServeConfig::max_queue` in-flight requests per
//! lane it rejects with [`SubmitError::QueueFull`] (backpressure) — a
//! linger window accumulates batches, and whichever worker wakes first
//! flushes the window. No thread ever sleep-polls.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::ckks::linear::{hom_linear, SlotMatrix};
use crate::ckks::program::{FheProgram, OpCode, ProgramError};
use crate::ckks::{bsgs_geometry, Ciphertext, Evaluator, MissingKey, RnsPoly};
use crate::codegen::{Backend, Compiler, SimParams};
use crate::gpusim::{simulate_trace, GpuConfig};
use crate::isa::Trace;
use crate::telemetry::{self, LatencyHist, Stage, WorkSnapshot, OP_GROUPS, STAGE_COUNT};

/// The homomorphic op sequences a single-op request can ask for. Whole
/// ciphertext DAGs travel as [`ProgramRequest`] instead (`submit_program`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum OpKind {
    /// dot(w, x) + b via rotate-and-sum — encrypted linear scoring
    /// against the server-side model weights.
    LinearScore,
    /// One ciphertext-ciphertext self-product (with relinearization).
    Square,
    /// Slot rotation by k.
    Rotate(usize),
    /// Complex conjugation of every slot.
    Conjugate,
    /// Ciphertext-ciphertext product (binary: needs `Request::ct2`).
    Mul,
    /// Ciphertext-ciphertext addition (binary: needs `Request::ct2`).
    Add,
    /// Ciphertext-ciphertext subtraction (binary: needs `Request::ct2`).
    Sub,
    /// Negation of every slot.
    Negate,
    /// Scalar slot product (PtMult by a constant; burns one level).
    MulConst(f64),
    /// Scalar slot addition (level-neutral).
    AddConst(f64),
    /// Plaintext-ciphertext product with rescale (needs `Request::pt`).
    MulPlain,
    /// Drop to the given level without dividing (exact in RNS).
    LevelReduce(usize),
    /// Drop one level by dividing out the top prime.
    Rescale,
    /// BSGS dense linear transform (needs `Request::matrix`).
    HomLinear,
    /// Exact BFV ciphertext-ciphertext product (binary: needs
    /// `Request::ct2`; BFV-scheme engines only).
    BfvMul,
}

/// Which hardware class an op exercises (the paper's split: key-switch
/// pipelines on the FHEC Tensor-Core path, elementwise ops on CUDA cores).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpClass {
    /// Key-switch heavy: mul / rotate / conjugate / linear transforms.
    Fhec,
    /// Elementwise only: add / rescale.
    Cuda,
}

impl OpClass {
    pub const COUNT: usize = 2;

    pub fn index(self) -> usize {
        match self {
            OpClass::Fhec => 0,
            OpClass::Cuda => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OpClass::Fhec => "fhec",
            OpClass::Cuda => "cuda",
        }
    }
}

impl OpKind {
    /// Routing classification: everything that key-switches is FHEC-class;
    /// the elementwise/plaintext ops ride the CUDA lane.
    pub fn class(self) -> OpClass {
        match self {
            OpKind::Add
            | OpKind::Sub
            | OpKind::Negate
            | OpKind::MulConst(_)
            | OpKind::AddConst(_)
            | OpKind::MulPlain
            | OpKind::LevelReduce(_)
            | OpKind::Rescale => OpClass::Cuda,
            _ => OpClass::Fhec,
        }
    }

    /// Binary ops consume a second ciphertext operand.
    pub fn needs_ct2(self) -> bool {
        matches!(self, OpKind::Mul | OpKind::Add | OpKind::Sub | OpKind::BfvMul)
    }

    /// Matrix ops consume a slot matrix operand.
    pub fn needs_matrix(self) -> bool {
        matches!(self, OpKind::HomLinear)
    }

    /// Plaintext ops consume a plaintext polynomial operand.
    pub fn needs_pt(self) -> bool {
        matches!(self, OpKind::MulPlain)
    }

    /// Ops that rescale somewhere in their pipeline: they consume one
    /// level and are inadmissible at level 0.
    pub fn consumes_level(self) -> bool {
        matches!(
            self,
            OpKind::LinearScore
                | OpKind::Square
                | OpKind::Mul
                | OpKind::MulConst(_)
                | OpKind::MulPlain
                | OpKind::Rescale
                | OpKind::HomLinear
        )
    }
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub op: OpKind,
    pub ct: Ciphertext,
    /// Second operand for binary ops (`Mul`, `Add`, `Sub`).
    pub ct2: Option<Ciphertext>,
    /// Matrix operand for `HomLinear`.
    pub matrix: Option<SlotMatrix>,
    /// Plaintext operand for `MulPlain`.
    pub pt: Option<RnsPoly>,
}

impl Request {
    pub fn new(id: u64, op: OpKind, ct: Ciphertext) -> Self {
        Self { id, op, ct, ct2: None, matrix: None, pt: None }
    }

    pub fn with_ct2(mut self, ct2: Ciphertext) -> Self {
        self.ct2 = Some(ct2);
        self
    }

    pub fn with_matrix(mut self, matrix: SlotMatrix) -> Self {
        self.matrix = Some(matrix);
        self
    }

    pub fn with_pt(mut self, pt: RnsPoly) -> Self {
        self.pt = Some(pt);
        self
    }
}

/// A whole-ciphertext-DAG request: the program API's serving unit. One
/// admission, one lane dispatch, one response — however many ops the DAG
/// fuses (and the rotation fan-outs inside share hoisted key-switch
/// decompositions).
#[derive(Debug)]
pub struct ProgramRequest {
    pub id: u64,
    pub program: Arc<FheProgram>,
    /// Bound positionally to the program's declared inputs.
    pub inputs: Vec<Ciphertext>,
}

impl ProgramRequest {
    pub fn new(id: u64, program: Arc<FheProgram>, inputs: Vec<Ciphertext>) -> Self {
        Self { id, program, inputs }
    }
}

pub struct ProgramResponse {
    pub id: u64,
    /// The program's outputs in declaration order — or the typed
    /// [`ProgramError`] (key gaps surface here as `MissingKey`).
    pub outputs: Result<Vec<Ciphertext>, ProgramError>,
    /// Wall-clock service time of the whole program.
    pub service: Duration,
    /// Simulated A100 / A100+FHECore latency for the program's op mix.
    pub sim_base_us: f64,
    pub sim_fhec_us: f64,
    pub batch_size: usize,
}

/// Why a program submission was not admitted.
#[derive(Debug, Clone, PartialEq)]
pub enum ProgramSubmitError {
    /// Typed validation failure — retrying the same program cannot help.
    Invalid(ProgramError),
    /// The program's lane is at `max_queue`.
    QueueFull { depth: usize },
    /// The coordinator is shutting down.
    Stopped,
}

impl std::fmt::Display for ProgramSubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ProgramSubmitError::Invalid(e) => write!(f, "invalid program: {e}"),
            ProgramSubmitError::QueueFull { depth } => {
                write!(f, "serving queue full ({depth} in flight)")
            }
            ProgramSubmitError::Stopped => write!(f, "coordinator stopped"),
        }
    }
}

impl std::error::Error for ProgramSubmitError {}

pub struct Response {
    pub id: u64,
    /// The homomorphic result — or the typed failure when the public key
    /// set lacks a key the op needs.
    pub ct: Result<Ciphertext, MissingKey>,
    /// Wall-clock service time of the functional path.
    pub service: Duration,
    /// Simulated A100 / A100+FHECore latency for this request's op mix.
    pub sim_base_us: f64,
    pub sim_fhec_us: f64,
    pub batch_size: usize,
}

/// Shared server-side model state (plaintext weights etc.).
///
/// `weights_pt` must be encoded at the context's max level; `LinearScore`
/// truncates its chain down to each request's level.
pub struct ModelState {
    pub weights_pt: RnsPoly,
    pub rot_steps: usize,
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Workers on the FHEC-class (key-switch) lane.
    pub fhec_workers: usize,
    /// Workers on the CUDA-class (elementwise) lane.
    pub cuda_workers: usize,
    pub max_batch: usize,
    pub linger: Duration,
    /// Per-lane bound on admitted-but-unclaimed requests (pending window +
    /// queued batches). `submit` rejects beyond this — backpressure, not
    /// OOM.
    pub max_queue: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            fhec_workers: 2,
            cuda_workers: 1,
            max_batch: 8,
            linger: Duration::from_millis(2),
            max_queue: 64,
        }
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub queue_peak: AtomicUsize,
    pub total_service_us: AtomicU64,
    /// Submissions rejected by backpressure.
    pub rejected: AtomicU64,
    /// Requests served per lane.
    pub fhec_served: AtomicU64,
    pub cuda_served: AtomicU64,
    /// Whole-program requests served (each also counts once in `served`
    /// and its lane counter).
    pub programs: AtomicU64,
}

impl Metrics {
    pub fn mean_service_us(&self) -> f64 {
        let n = self.served.load(Ordering::Relaxed).max(1);
        self.total_service_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.served.load(Ordering::Relaxed) as f64 / b as f64
    }
}

/// A plain-data copy of the serving counters plus the instantaneous
/// per-lane queue depths — what the wire `Metrics` RPC ships and the CLI
/// prints.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub served: u64,
    pub batches: u64,
    pub rejected: u64,
    pub queue_peak: u64,
    pub mean_service_us: f64,
    pub mean_batch: f64,
    /// Current depth of the FHEC-class queue.
    pub fhec_depth: u64,
    /// Current depth of the CUDA-class queue.
    pub cuda_depth: u64,
    pub fhec_served: u64,
    pub cuda_served: u64,
    /// Whole-program requests served.
    pub programs: u64,
    /// Which [`crate::ckks::mlt_backend`] executes `ModLinKernel` tiles
    /// on this node (wire v4): a [`crate::ckks::mlt_backend::codes`]
    /// byte, `0` = unknown (pre-v4 peer), `255` = a cluster aggregate
    /// over shards running different backends.
    pub mlt_backend: u8,
    // --- wire v5: the multi-tenant registry/pool block -------------------
    /// Tenants whose `EvalKeySet` is expanded in memory right now.
    pub tenants_resident: u32,
    /// Tenants demoted to their seed-compressed cold blob.
    pub tenants_cold: u32,
    /// Tenant lookups answered from a resident key set.
    pub registry_hits: u64,
    /// Tenant lookups that found the tenant cold (each triggers one
    /// re-expansion, however many requests piled up behind it).
    pub registry_misses: u64,
    /// Resident key sets demoted to cold blobs by the LRU budget.
    pub key_evictions: u64,
    /// Cold-blob re-expansions performed.
    pub key_expansions: u64,
    /// Total wall-clock µs spent re-expanding cold blobs.
    pub expansion_us: u64,
    /// Bytes held by resident (expanded) key sets.
    pub resident_key_bytes: u64,
    /// Key-switch staging buffers served from the shared pool.
    pub pool_hits: u64,
    /// Pool checkouts that had to allocate a fresh scratch.
    pub pool_misses: u64,
    /// High-water mark of bytes held by the pool (idle + leased).
    pub pool_bytes_hwm: u64,
    /// Requests bounced with `Overloaded` (key budget, not queue).
    pub overloaded: u64,
    // --- wire v6: the cross-tenant batch-former block --------------------
    /// Fused dispatches the batch former executed (any occupancy).
    pub fused_dispatches: u64,
    /// Member ops carried by those fused dispatches.
    pub fused_members: u64,
    /// Highest occupancy any fused dispatch reached.
    pub fused_occupancy_peak: u64,
    /// Fused-dispatch count per occupancy bucket: 1, 2–3, 4–7, 8+.
    pub fused_hist: [u64; 4],
    /// Ops queued in the batch former right now.
    pub sched_depth: u64,
    /// Submissions bounced by the batch former's own queue bound.
    pub sched_rejected: u64,
    // --- wire v7: the telemetry block ------------------------------------
    /// Queue-wait latency histogram (admission → claim), covering both
    /// the coordinator lanes and the batch former's deadline window.
    pub queue_wait_hist: LatencyHist,
    /// Execute-time histograms per op-kind group, index-aligned with
    /// [`telemetry::OP_GROUP_NAMES`] — the wait/execute split.
    pub exec_hist: [LatencyHist; OP_GROUPS],
    /// Per-stage latency histograms, [`Stage::ALL`] order.
    pub stage_hist: [LatencyHist; STAGE_COUNT],
    /// Total ns spent per stage, [`Stage::ALL`] order.
    pub stage_ns: [u64; STAGE_COUNT],
    /// Requests that exceeded `--slow-request-ms` (0 threshold = never).
    pub slow_requests: u64,
    /// Trace-ring overwrites: span events lost to overload before any
    /// `client trace` drained them.
    pub trace_dropped: u64,
    /// Dynamic work accounting per primitive (calls, MLT tile-ops,
    /// butterfly-equivalents, Barrett reductions).
    pub work: WorkSnapshot,
}

impl MetricsSnapshot {
    /// Mean members per fused dispatch (0 when the batch former never
    /// fired).
    pub fn mean_fused_occupancy(&self) -> f64 {
        if self.fused_dispatches == 0 {
            0.0
        } else {
            self.fused_members as f64 / self.fused_dispatches as f64
        }
    }

    /// Fold another node's snapshot into this one — the cluster view is
    /// the sum of its shards: counters and lane depths add
    /// (*saturating*: a long-lived gateway aggregating many shards must
    /// pin at `u64::MAX` rather than wrap back to small numbers — a
    /// wrapped counter reads as a healthy restart, a pinned one as the
    /// overflow it is), the peaks are the max of peaks, and the means
    /// are re-derived served-weighted.
    pub fn absorb(&mut self, other: &MetricsSnapshot) {
        let total_us = self.mean_service_us * self.served as f64
            + other.mean_service_us * other.served as f64;
        self.served = self.served.saturating_add(other.served);
        self.batches = self.batches.saturating_add(other.batches);
        self.rejected = self.rejected.saturating_add(other.rejected);
        self.queue_peak = self.queue_peak.max(other.queue_peak);
        self.mean_service_us =
            if self.served > 0 { total_us / self.served as f64 } else { 0.0 };
        self.mean_batch = if self.batches > 0 {
            self.served as f64 / self.batches as f64
        } else {
            0.0
        };
        self.fhec_depth = self.fhec_depth.saturating_add(other.fhec_depth);
        self.cuda_depth = self.cuda_depth.saturating_add(other.cuda_depth);
        self.fhec_served = self.fhec_served.saturating_add(other.fhec_served);
        self.cuda_served = self.cuda_served.saturating_add(other.cuda_served);
        self.programs = self.programs.saturating_add(other.programs);
        self.tenants_resident = self.tenants_resident.saturating_add(other.tenants_resident);
        self.tenants_cold = self.tenants_cold.saturating_add(other.tenants_cold);
        self.registry_hits = self.registry_hits.saturating_add(other.registry_hits);
        self.registry_misses = self.registry_misses.saturating_add(other.registry_misses);
        self.key_evictions = self.key_evictions.saturating_add(other.key_evictions);
        self.key_expansions = self.key_expansions.saturating_add(other.key_expansions);
        self.expansion_us = self.expansion_us.saturating_add(other.expansion_us);
        self.resident_key_bytes =
            self.resident_key_bytes.saturating_add(other.resident_key_bytes);
        self.pool_hits = self.pool_hits.saturating_add(other.pool_hits);
        self.pool_misses = self.pool_misses.saturating_add(other.pool_misses);
        // A high-water mark aggregates like the queue peak: max, not sum.
        self.pool_bytes_hwm = self.pool_bytes_hwm.max(other.pool_bytes_hwm);
        self.overloaded = self.overloaded.saturating_add(other.overloaded);
        self.fused_dispatches = self.fused_dispatches.saturating_add(other.fused_dispatches);
        self.fused_members = self.fused_members.saturating_add(other.fused_members);
        // An occupancy peak aggregates like the other peaks: max, not sum.
        self.fused_occupancy_peak = self.fused_occupancy_peak.max(other.fused_occupancy_peak);
        // Every histogram-shaped counter merges through the one shared
        // bucket-wise helper — same edges on every producer, so a sum per
        // bucket IS the union histogram (no rebinning).
        telemetry::merge_buckets(&mut self.fused_hist, &other.fused_hist);
        self.sched_depth = self.sched_depth.saturating_add(other.sched_depth);
        self.sched_rejected = self.sched_rejected.saturating_add(other.sched_rejected);
        self.queue_wait_hist.merge(&other.queue_wait_hist);
        for (mine, theirs) in self.exec_hist.iter_mut().zip(other.exec_hist.iter()) {
            mine.merge(theirs);
        }
        for (mine, theirs) in self.stage_hist.iter_mut().zip(other.stage_hist.iter()) {
            mine.merge(theirs);
        }
        telemetry::merge_buckets(&mut self.stage_ns, &other.stage_ns);
        self.slow_requests = self.slow_requests.saturating_add(other.slow_requests);
        self.trace_dropped = self.trace_dropped.saturating_add(other.trace_dropped);
        for (mine, theirs) in self.work.rows.iter_mut().zip(other.work.rows.iter()) {
            mine.calls = mine.calls.saturating_add(theirs.calls);
            mine.tile_ops = mine.tile_ops.saturating_add(theirs.tile_ops);
            mine.butterflies = mine.butterflies.saturating_add(theirs.butterflies);
            mine.barrett = mine.barrett.saturating_add(theirs.barrett);
        }
        // Backends don't sum: agree → keep, one side unknown → take the
        // known one, genuine disagreement → flag the aggregate as mixed.
        self.mlt_backend = match (self.mlt_backend, other.mlt_backend) {
            (a, b) if a == b => a,
            (crate::ckks::mlt_backend::codes::UNKNOWN, b) => b,
            (a, crate::ckks::mlt_backend::codes::UNKNOWN) => a,
            _ => crate::ckks::mlt_backend::codes::MIXED,
        };
    }
}

/// Why a submission was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The op's lane is at `max_queue` — shed load or retry later.
    QueueFull { depth: usize },
    /// The request is structurally invalid (missing operand, level 0
    /// rescale...). Retrying the same request can never succeed.
    BadRequest(&'static str),
    /// The coordinator is shutting down.
    Stopped,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { depth } => {
                write!(f, "serving queue full ({depth} in flight)")
            }
            SubmitError::BadRequest(why) => write!(f, "bad request: {why}"),
            SubmitError::Stopped => write!(f, "coordinator stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Scheme admissibility of a single op: BFV engines serve only the exact
/// subset (elementwise, Galois, and the BEHZ multiply), CKKS engines
/// everything *except* the BEHZ multiply. Returns the rejection reason,
/// or `None` when the op is admissible. Shared by the coordinator's
/// `submit` and the wire server's request decode so both reject
/// identically.
pub fn scheme_rejects(scheme: crate::bfv::Scheme, op: OpKind) -> Option<&'static str> {
    use crate::bfv::Scheme;
    match scheme {
        Scheme::Ckks => {
            matches!(op, OpKind::BfvMul).then_some("BfvMul needs a BFV-scheme engine")
        }
        Scheme::Bfv => (!matches!(
            op,
            OpKind::Add
                | OpKind::Sub
                | OpKind::Negate
                | OpKind::Rotate(_)
                | OpKind::Conjugate
                | OpKind::BfvMul
        ))
        .then_some("op not admissible on a BFV-scheme engine"),
    }
}

/// [`scheme_rejects`] for one program op.
pub fn scheme_rejects_opcode(scheme: crate::bfv::Scheme, op: &OpCode) -> Option<&'static str> {
    use crate::bfv::Scheme;
    match scheme {
        Scheme::Ckks => {
            matches!(op, OpCode::BfvMul(_, _)).then_some("BfvMul needs a BFV-scheme engine")
        }
        Scheme::Bfv => (!matches!(
            op,
            OpCode::Add(_, _)
                | OpCode::Sub(_, _)
                | OpCode::Negate(_)
                | OpCode::Rotate(_, _)
                | OpCode::Conjugate(_)
                | OpCode::BfvMul(_, _)
        ))
        .then_some("op not admissible on a BFV-scheme engine"),
    }
}

/// One admitted unit of work: a single op or a whole program. Both count
/// as one toward the lane's bounded depth.
enum Job {
    Op(Request, Sender<Response>),
    Program(ProgramRequest, Sender<ProgramResponse>),
}

struct QueueState {
    /// The open linger window (each job with its admission instant, so
    /// the claiming worker can attribute the queue wait).
    pending: Vec<(Job, Instant)>,
    window_start: Instant,
    /// Batches ready for a worker.
    batches: VecDeque<Vec<(Job, Instant)>>,
    /// pending.len() + sum of queued batch sizes (the bounded quantity).
    depth: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    cv: Condvar,
}

fn new_shared() -> Arc<Shared> {
    Arc::new(Shared {
        state: Mutex::new(QueueState {
            pending: Vec::new(),
            window_start: Instant::now(),
            batches: VecDeque::new(),
            depth: 0,
            shutdown: false,
        }),
        cv: Condvar::new(),
    })
}

/// The coordinator: `submit()` requests, receive [`Response`]s on the
/// returned channel. Dropping it drains queued batches and joins the
/// worker threads.
pub struct Coordinator {
    /// One queue per [`OpClass`], indexed by `OpClass::index()`.
    lanes: [Arc<Shared>; OpClass::COUNT],
    pub metrics: Arc<Metrics>,
    cfg: ServeConfig,
    /// Slot count of the served context (admission checks on matrices).
    slots: usize,
    /// The served evaluator — admission-time program validation runs
    /// against its context + public key set.
    ev: Arc<Evaluator>,
    /// The process-wide cross-tenant batch former, when one is attached
    /// and enabled: fusable FHEC-class single ops drain into it instead
    /// of this tenant's own lane.
    sched: Option<Arc<crate::sched::BatchScheduler>>,
    /// This coordinator's tenant identity in the batch former's fairness
    /// accounting (the key-blob fingerprint on the wire path).
    tenant: u64,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn both lanes' worker pools. `ev` (context + public
    /// `EvalKeySet`) and `model` are shared read-only; no secret key is
    /// ever handed over.
    pub fn start(ev: Arc<Evaluator>, model: Arc<ModelState>, cfg: ServeConfig) -> Self {
        Self::start_with_scheduler(ev, model, cfg, None, 0)
    }

    /// [`Coordinator::start`], plus a shared cross-tenant
    /// [`BatchScheduler`](crate::sched::BatchScheduler): fusable ops
    /// (rotate/conjugate/square/mul away from Galois identity — see
    /// [`crate::sched::compat_key`]) are routed to it under `tenant`'s
    /// identity. A scheduler whose window is zero is ignored — the
    /// `--batch-window-us 0` degenerate case IS the sequential lane path.
    pub fn start_with_scheduler(
        ev: Arc<Evaluator>,
        model: Arc<ModelState>,
        cfg: ServeConfig,
        sched: Option<Arc<crate::sched::BatchScheduler>>,
        tenant: u64,
    ) -> Self {
        let sched = sched.filter(|s| s.config().enabled());
        let lanes = [new_shared(), new_shared()];
        let metrics = Arc::new(Metrics::default());
        let slots = ev.ctx.params.slots();
        let mut workers = Vec::new();
        for class in [OpClass::Fhec, OpClass::Cuda] {
            let count = match class {
                OpClass::Fhec => cfg.fhec_workers.max(1),
                OpClass::Cuda => cfg.cuda_workers.max(1),
            };
            for _ in 0..count {
                let shared = lanes[class.index()].clone();
                let ev = ev.clone();
                let model = model.clone();
                let metrics = metrics.clone();
                let cfg = cfg.clone();
                workers.push(std::thread::spawn(move || {
                    worker_loop(&shared, &ev, &model, &cfg, &metrics, class, tenant)
                }));
            }
        }
        Self {
            lanes,
            metrics,
            cfg,
            slots,
            ev,
            sched,
            tenant,
            workers,
        }
    }

    /// Admit a request into its lane's bounded queue. Returns the response
    /// channel, or hands the request back with the typed [`SubmitError`]
    /// so the caller can shed or retry it.
    ///
    /// Structural validation happens here, at admission: anything that
    /// would trip an assert deep inside a worker (and kill the lane
    /// thread) bounces as [`SubmitError::BadRequest`] instead.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>, (Request, SubmitError)> {
        if let Some(why) = scheme_rejects(self.ev.scheme(), req.op) {
            return Err((req, SubmitError::BadRequest(why)));
        }
        if req.op.needs_ct2() && req.ct2.is_none() {
            return Err((req, SubmitError::BadRequest("binary op without ct2")));
        }
        if req.op.needs_matrix() && req.matrix.is_none() {
            return Err((req, SubmitError::BadRequest("HomLinear without matrix")));
        }
        // Level-consuming ops run at the operands' *common* (minimum)
        // level after alignment — that is what must be nonzero.
        let effective_level = req
            .ct2
            .as_ref()
            .map(|c| c.level.min(req.ct.level))
            .unwrap_or(req.ct.level);
        if req.op.consumes_level() && effective_level == 0 {
            return Err((req, SubmitError::BadRequest("no level left to rescale into")));
        }
        if let Some(ct2) = &req.ct2 {
            // The same window `Evaluator::align` asserts on.
            let ratio = req.ct.scale / ct2.scale;
            if !crate::ckks::ops::SCALE_RATIO_TOLERANCE.contains(&ratio) {
                return Err((req, SubmitError::BadRequest("operand scale mismatch")));
            }
        }
        if let Some(m) = &req.matrix {
            if m.dim != self.slots {
                return Err((req, SubmitError::BadRequest("matrix dim != slot count")));
            }
            // hom_linear skips empty diagonals and panics if *none* are
            // nonzero (same epsilon); an all-zero matrix has no answer.
            if m.entries.iter().all(|c| c.abs() < 1e-12) {
                return Err((req, SubmitError::BadRequest("matrix has no nonzero entry")));
            }
        }
        if req.op.needs_pt() {
            if req.pt.is_none() {
                return Err((req, SubmitError::BadRequest("MulPlain without plaintext")));
            }
            if req.ct.level >= self.ev.ctx.q_chain.len() {
                return Err((req, SubmitError::BadRequest("operand level beyond chain depth")));
            }
            if let Some(pt) = &req.pt {
                if pt.n != 2 * self.slots {
                    return Err((req, SubmitError::BadRequest("plaintext ring dim mismatch")));
                }
                // Exact chain identity, not just length — the pointwise
                // product's zip_check asserts on it (same rule as the
                // program path's check_pt).
                if pt.chain != self.ev.ctx.chain_at(req.ct.level) {
                    return Err((
                        req,
                        SubmitError::BadRequest("plaintext chain does not match operand level"),
                    ));
                }
            }
        }
        match req.op {
            OpKind::MulConst(v) | OpKind::AddConst(v) if !v.is_finite() => {
                return Err((req, SubmitError::BadRequest("non-finite scalar operand")));
            }
            OpKind::LevelReduce(target) if target > req.ct.level => {
                return Err((
                    req,
                    SubmitError::BadRequest("level_reduce target above operand level"),
                ));
            }
            _ => {}
        }
        // Cross-tenant batch former: fusable ops drain into the shared
        // scheduler (same validation above — the scheduler trusts its
        // submitters), everything else rides this tenant's own lanes.
        if let Some(sched) = &self.sched {
            if let Some(key) = crate::sched::compat_key(&self.ev, &req) {
                let (rtx, rrx) = channel();
                let job = crate::sched::SchedJob {
                    tenant: self.tenant,
                    ev: self.ev.clone(),
                    metrics: self.metrics.clone(),
                    key,
                    req,
                    reply: rtx,
                    admitted: Instant::now(),
                };
                return match sched.submit(job) {
                    Ok(()) => Ok(rrx),
                    Err((job, e)) => {
                        let req = job.req;
                        match e {
                            crate::sched::SchedSubmitError::QueueFull { depth } => {
                                // Backpressure is backpressure, whichever
                                // queue bounced it: count it against this
                                // tenant too.
                                self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                                Err((req, SubmitError::QueueFull { depth }))
                            }
                            crate::sched::SchedSubmitError::Stopped => {
                                Err((req, SubmitError::Stopped))
                            }
                        }
                    }
                };
            }
        }
        let class = req.op.class();
        let (rtx, rrx) = channel();
        match self.enqueue(class, Job::Op(req, rtx)) {
            Ok(()) => Ok(rrx),
            Err((Job::Op(req, _), rejection)) => Err((req, rejection)),
            Err(_) => unreachable!("enqueue hands back the job it was given"),
        }
    }

    /// Admit a whole-program request: full typed validation against the
    /// serving context and public key set at admission ([`ProgramError`]
    /// — nothing reaches a worker assert), then one slot in the lane the
    /// program's op mix classifies into (FHEC if any op key-switches).
    pub fn submit_program(
        &self,
        req: ProgramRequest,
    ) -> Result<Receiver<ProgramResponse>, (ProgramRequest, ProgramSubmitError)> {
        for (i, op) in req.program.ops().iter().enumerate() {
            if let Some(why) = scheme_rejects_opcode(self.ev.scheme(), op) {
                let e = ProgramError::BadOperand { op: i, why: why.into() };
                return Err((req, ProgramSubmitError::Invalid(e)));
            }
        }
        let meta: Vec<(usize, f64)> =
            req.inputs.iter().map(|c| (c.level, c.scale)).collect();
        if let Err(e) = req.program.validate(&self.ev.ctx, self.ev.keys(), &meta) {
            return Err((req, ProgramSubmitError::Invalid(e)));
        }
        let class = if req.program.has_keyswitch() {
            OpClass::Fhec
        } else {
            OpClass::Cuda
        };
        let (rtx, rrx) = channel();
        match self.enqueue(class, Job::Program(req, rtx)) {
            Ok(()) => Ok(rrx),
            Err((Job::Program(req, _), SubmitError::QueueFull { depth })) => {
                Err((req, ProgramSubmitError::QueueFull { depth }))
            }
            Err((Job::Program(req, _), SubmitError::Stopped)) => {
                Err((req, ProgramSubmitError::Stopped))
            }
            Err(_) => unreachable!("enqueue hands back the job it was given"),
        }
    }

    /// Push one admitted job into its lane's bounded queue (the shared
    /// tail of `submit` / `submit_program`).
    fn enqueue(&self, class: OpClass, job: Job) -> Result<(), (Job, SubmitError)> {
        let lane = &self.lanes[class.index()];
        let mut st = lane.state.lock().unwrap();
        if st.shutdown {
            return Err((job, SubmitError::Stopped));
        }
        if st.depth >= self.cfg.max_queue {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err((job, SubmitError::QueueFull { depth: st.depth }));
        }
        if st.pending.is_empty() {
            st.window_start = Instant::now();
        }
        st.pending.push((job, Instant::now()));
        st.depth += 1;
        self.metrics.queue_peak.fetch_max(st.depth, Ordering::Relaxed);
        if st.pending.len() >= self.cfg.max_batch {
            let batch = std::mem::take(&mut st.pending);
            st.batches.push_back(batch);
        }
        drop(st);
        // One worker suffices: it either claims a promoted batch or
        // becomes the timed waiter that flushes the linger window.
        // (notify_all here would stampede every idle worker per request.)
        lane.cv.notify_one();
        Ok(())
    }

    /// Instantaneous queue depth per lane, `[fhec, cuda]`.
    pub fn queue_depths(&self) -> [usize; OpClass::COUNT] {
        let mut out = [0usize; OpClass::COUNT];
        for (i, lane) in self.lanes.iter().enumerate() {
            out[i] = lane.state.lock().unwrap().depth;
        }
        out
    }

    /// Plain-data snapshot of the counters + live queue depths (the wire
    /// `Metrics` RPC payload).
    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = &self.metrics;
        let depths = self.queue_depths();
        MetricsSnapshot {
            served: m.served.load(Ordering::Relaxed),
            batches: m.batches.load(Ordering::Relaxed),
            rejected: m.rejected.load(Ordering::Relaxed),
            queue_peak: m.queue_peak.load(Ordering::Relaxed) as u64,
            mean_service_us: m.mean_service_us(),
            mean_batch: m.mean_batch(),
            fhec_depth: depths[OpClass::Fhec.index()] as u64,
            cuda_depth: depths[OpClass::Cuda.index()] as u64,
            fhec_served: m.fhec_served.load(Ordering::Relaxed),
            cuda_served: m.cuda_served.load(Ordering::Relaxed),
            programs: m.programs.load(Ordering::Relaxed),
            mlt_backend: crate::ckks::mlt_backend::active().code(),
            // The registry/pool block is zero here: a coordinator serves
            // one tenant's keys and owns neither the registry nor the
            // pool. The wire server injects those stats into the summed
            // snapshot (`server::registry_snapshot`).
            ..MetricsSnapshot::default()
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        for lane in &self.lanes {
            let mut st = lane.state.lock().unwrap();
            st.shutdown = true;
            // Graceful drain: promote the open window so nothing admitted
            // is silently dropped.
            if !st.pending.is_empty() {
                let batch = std::mem::take(&mut st.pending);
                st.batches.push_back(batch);
            }
            drop(st);
            lane.cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim the next batch: a full/queued one immediately, the open linger
/// window once it ages past `linger`, or `None` on shutdown with an empty
/// queue. Blocks on the condvar — no sleep-polling.
fn claim_batch(shared: &Shared, cfg: &ServeConfig) -> Option<Vec<(Job, Instant)>> {
    let mut st = shared.state.lock().unwrap();
    loop {
        if let Some(b) = st.batches.pop_front() {
            st.depth -= b.len();
            return Some(b);
        }
        if !st.pending.is_empty() {
            let elapsed = st.window_start.elapsed();
            if elapsed >= cfg.linger {
                let batch = std::mem::take(&mut st.pending);
                st.depth -= batch.len();
                return Some(batch);
            }
            // Sleep exactly until the window closes (or new work arrives).
            let (guard, _) = shared.cv.wait_timeout(st, cfg.linger - elapsed).unwrap();
            st = guard;
            continue;
        }
        if st.shutdown {
            return None;
        }
        st = shared.cv.wait(st).unwrap();
    }
}

fn worker_loop(
    shared: &Shared,
    ev: &Evaluator,
    model: &ModelState,
    cfg: &ServeConfig,
    metrics: &Metrics,
    class: OpClass,
    tenant: u64,
) {
    while let Some(batch) = claim_batch(shared, cfg) {
        serve_batch(batch, ev, model, metrics, class, tenant);
    }
}

/// Latency-histogram op-kind grouping, index-aligned with
/// [`telemetry::OP_GROUP_NAMES`]: rotations, relinearizing products,
/// elementwise, linear transforms — group 4 ([`PROGRAM_GROUP`]) is
/// whole-program requests.
pub(crate) fn op_group(op: OpKind) -> usize {
    match op {
        OpKind::Rotate(_) | OpKind::Conjugate => 0,
        OpKind::Mul | OpKind::Square | OpKind::BfvMul => 1,
        OpKind::LinearScore | OpKind::HomLinear => 3,
        _ => 2,
    }
}

pub(crate) const PROGRAM_GROUP: usize = 4;

/// Build the timing-model trace for one request's op mix. `pub(crate)`
/// so the batch former's fused dispatches carry the same dual-dispatch
/// sim timings as the sequential lane path.
pub(crate) fn request_trace(op: OpKind, level: usize, ev: &Evaluator, backend: Backend) -> Trace {
    let p = SimParams {
        n: ev.ctx.params.n.max(256),
        l: level + 1,
        alpha: ev.ctx.p_chain.len().max(1),
        dnum: ev.ctx.params.dnum,
    };
    let c = Compiler::new(backend);
    match op {
        OpKind::LinearScore => {
            let mut t = c.ptmult(&p);
            let rot_steps = (ev.ctx.params.slots() as f64).log2().ceil() as usize;
            for _ in 0..rot_steps {
                t.extend(c.rotate(&p));
                t.extend(c.headd(&p));
            }
            t
        }
        // The BEHZ multiply runs the same tensor + key-switch pipeline
        // shape as HEMult (extended-base work folds into the same trace).
        OpKind::Square | OpKind::Mul | OpKind::BfvMul => c.hemult(&p),
        OpKind::Rotate(_) | OpKind::Conjugate => c.rotate(&p),
        OpKind::Add | OpKind::Sub | OpKind::Negate | OpKind::AddConst(_)
        | OpKind::LevelReduce(_) => c.headd(&p),
        OpKind::MulConst(_) | OpKind::MulPlain => c.ptmult(&p),
        OpKind::Rescale => c.rescale(&p),
        OpKind::HomLinear => {
            // BSGS: g-1 baby + outer-1 giant rotations, one PtMult+HEAdd
            // per non-empty diagonal group.
            let (g, outer) = bsgs_geometry(ev.ctx.params.slots());
            let mut t = Trace::default();
            for _ in 0..(g - 1) + (outer.saturating_sub(1)) {
                t.extend(c.rotate(&p));
            }
            for _ in 0..outer {
                t.extend(c.ptmult(&p));
                t.extend(c.headd(&p));
            }
            t
        }
    }
}

/// Build the timing-model trace for a whole program: the per-op traces
/// summed over the DAG. (The hoisted fan-outs execute fewer BConv passes
/// than this naive sum — the functional path is where that shows up; the
/// trace keeps the paper's per-primitive instruction accounting.)
fn program_trace(prog: &FheProgram, level: usize, ev: &Evaluator, backend: Backend) -> Trace {
    let mut t = Trace::default();
    for op in prog.ops() {
        let kind = match op {
            OpCode::Mul(_, _) => OpKind::Mul,
            OpCode::BfvMul(_, _) => OpKind::BfvMul,
            OpCode::Square(_) => OpKind::Square,
            OpCode::Rotate(_, k) => OpKind::Rotate(*k),
            OpCode::Conjugate(_) => OpKind::Conjugate,
            OpCode::Add(_, _) => OpKind::Add,
            OpCode::Sub(_, _) => OpKind::Sub,
            OpCode::Negate(_) => OpKind::Negate,
            OpCode::AddConst(_, v) => OpKind::AddConst(*v),
            OpCode::MulConst(_, v) => OpKind::MulConst(*v),
            OpCode::MulPlain(_, _) | OpCode::MulPlainRaw(_, _) => OpKind::MulPlain,
            OpCode::Rescale(_) => OpKind::Rescale,
            OpCode::LevelReduce(_, l) => OpKind::LevelReduce(*l),
            OpCode::HomLinear(_, _) => OpKind::HomLinear,
        };
        t.extend(request_trace(kind, level, ev, backend));
    }
    t
}

/// Execute one request against the public key set.
fn execute(ev: &Evaluator, model: &ModelState, req: &Request) -> Result<Ciphertext, MissingKey> {
    match req.op {
        OpKind::LinearScore => {
            // dot(w, x): PtMult then rotate-and-sum over all slots. The
            // weights are encoded at max_level; take only the limbs the
            // request's level needs (exact in RNS) so any level serves
            // without copying the full-depth polynomial.
            let nl = req.ct.level + 1;
            let w = RnsPoly {
                n: model.weights_pt.n,
                format: model.weights_pt.format,
                limbs: model.weights_pt.limbs[..nl].to_vec(),
                chain: model.weights_pt.chain[..nl].to_vec(),
            };
            let mut acc = ev.mul_plain(&req.ct, &w);
            let mut step = 1usize;
            while step < model.rot_steps {
                let rot = ev.rotate(&acc, step)?;
                acc = ev.add(&acc, &rot);
                step <<= 1;
            }
            Ok(acc)
        }
        OpKind::Square => ev.mul(&req.ct, &req.ct),
        OpKind::Rotate(k) => ev.rotate(&req.ct, k),
        OpKind::Conjugate => ev.conjugate(&req.ct),
        // Operand presence is validated at `submit` admission.
        OpKind::Mul => ev.mul(&req.ct, req.ct2.as_ref().expect("validated at submit")),
        OpKind::BfvMul => ev.bfv_mul(&req.ct, req.ct2.as_ref().expect("validated at submit")),
        OpKind::Add => Ok(ev.add(&req.ct, req.ct2.as_ref().expect("validated at submit"))),
        OpKind::Sub => Ok(ev.sub(&req.ct, req.ct2.as_ref().expect("validated at submit"))),
        OpKind::Negate => Ok(ev.negate(&req.ct)),
        OpKind::MulConst(v) => Ok(ev.mul_const(&req.ct, v)),
        OpKind::AddConst(v) => Ok(ev.add_const(&req.ct, v)),
        OpKind::MulPlain => {
            Ok(ev.mul_plain(&req.ct, req.pt.as_ref().expect("validated at submit")))
        }
        OpKind::LevelReduce(target) => Ok(ev.level_reduce(&req.ct, target)),
        OpKind::Rescale => Ok(ev.rescale(&req.ct)),
        OpKind::HomLinear => {
            hom_linear(ev, &req.ct, req.matrix.as_ref().expect("validated at submit"))
        }
    }
}

fn serve_batch(
    batch: Vec<(Job, Instant)>,
    ev: &Evaluator,
    model: &ModelState,
    metrics: &Metrics,
    class: OpClass,
    tenant: u64,
) {
    let gpu = GpuConfig::default();
    let n = batch.len();
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    let count_served = |service: Duration| {
        metrics.served.fetch_add(1, Ordering::Relaxed);
        match class {
            OpClass::Fhec => metrics.fhec_served.fetch_add(1, Ordering::Relaxed),
            OpClass::Cuda => metrics.cuda_served.fetch_add(1, Ordering::Relaxed),
        };
        metrics
            .total_service_us
            .fetch_add(service.as_micros() as u64, Ordering::Relaxed);
    };
    for (job, admitted) in batch {
        match job {
            Job::Op(req, reply) => {
                let t0 = Instant::now();
                // Attribution: every span the compute below records (NTT,
                // base conversion, ModDown...) carries this request id and
                // tenant fingerprint; the retro queue-wait span covers
                // admission -> claim.
                let scope = telemetry::request_scope(req.id, tenant);
                telemetry::record_span_at(Stage::QueueWait, admitted, t0, 0);
                telemetry::record_queue_wait(t0.saturating_duration_since(admitted));
                let exec_span = telemetry::span_with(Stage::Execute, n as u64);
                // Containment: admission validates everything we know can
                // trip an assert, but a panic from a bug must cost one
                // request, not the lane thread (a dead lane hangs every
                // queued + future request). Dropping `reply` without
                // sending surfaces as a typed "worker dropped the
                // request" error on the wire path.
                let out = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    execute(ev, model, &req)
                })) {
                    Ok(r) => r,
                    Err(_) => {
                        eprintln!(
                            "coordinator: request {} ({:?}) panicked; dropped",
                            req.id, req.op
                        );
                        continue;
                    }
                };
                drop(exec_span);
                let service = t0.elapsed();
                // Dual dispatch: the timing model for this op mix.
                let level = out.as_ref().map(|c| c.level).unwrap_or(req.ct.level);
                let base = request_trace(req.op, level, ev, Backend::A100);
                let fhec = request_trace(req.op, level, ev, Backend::A100Fhec);
                let sim_base_us = simulate_trace(&gpu, &base).latency_us(&gpu);
                let sim_fhec_us = simulate_trace(&gpu, &fhec).latency_us(&gpu);
                count_served(service);
                telemetry::record_exec(op_group(req.op), service);
                telemetry::maybe_log_slow(
                    req.id,
                    tenant,
                    &format!("{:?}", req.op),
                    n,
                    admitted.elapsed(),
                    &scope.breakdown(),
                );
                let _ = reply.send(Response {
                    id: req.id,
                    ct: out,
                    service,
                    sim_base_us,
                    sim_fhec_us,
                    batch_size: n,
                });
            }
            Job::Program(req, reply) => {
                let t0 = Instant::now();
                let scope = telemetry::request_scope(req.id, tenant);
                telemetry::record_span_at(Stage::QueueWait, admitted, t0, 0);
                telemetry::record_queue_wait(t0.saturating_duration_since(admitted));
                let prog_span = telemetry::span_with(Stage::Program, req.program.len() as u64);
                // Whole DAG as one unit: validated at admission (so the
                // worker skips the second pass), executed with hoisted
                // rotation fan-outs; same panic containment.
                let out = match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    ev.run_program_prevalidated(&req.program, &req.inputs)
                })) {
                    Ok(r) => r,
                    Err(_) => {
                        eprintln!(
                            "coordinator: program request {} ({} ops) panicked; dropped",
                            req.id,
                            req.program.len()
                        );
                        continue;
                    }
                };
                drop(prog_span);
                let service = t0.elapsed();
                let level = req.inputs.iter().map(|c| c.level).min().unwrap_or(0);
                let base = program_trace(&req.program, level, ev, Backend::A100);
                let fhec = program_trace(&req.program, level, ev, Backend::A100Fhec);
                let sim_base_us = simulate_trace(&gpu, &base).latency_us(&gpu);
                let sim_fhec_us = simulate_trace(&gpu, &fhec).latency_us(&gpu);
                count_served(service);
                metrics.programs.fetch_add(1, Ordering::Relaxed);
                telemetry::record_exec(PROGRAM_GROUP, service);
                telemetry::maybe_log_slow(
                    req.id,
                    tenant,
                    "Program",
                    n,
                    admitted.elapsed(),
                    &scope.breakdown(),
                );
                let _ = reply.send(ProgramResponse {
                    id: req.id,
                    outputs: out,
                    service,
                    sim_base_us,
                    sim_fhec_us,
                    batch_size: n,
                });
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Complex;
    use crate::ckks::params::{CkksContext, CkksParams};
    use crate::ckks::{Decryptor, Encryptor, EvalKeySpec, KeyGen, KeyKind};
    use crate::util::rng::Pcg64;

    fn setup() -> (Arc<Evaluator>, Encryptor, Decryptor, Arc<ModelState>, Pcg64) {
        let ctx = CkksContext::new(CkksParams::toy());
        let mut rng = Pcg64::new(0x5EEE);
        let kg = KeyGen::new(&ctx, &mut rng);
        let slots = ctx.params.slots();
        // Serving kit + the explicit step the Rotate(3) test uses.
        let spec = EvalKeySpec::serving(slots).with_rotations(&[3]);
        let keys = kg.eval_key_set(&ctx, &spec, &mut rng);
        let enc = kg.encryptor();
        let dec = kg.decryptor();
        let ev = Evaluator::new(ctx, Arc::new(keys));
        let w: Vec<Complex> = (0..slots)
            .map(|i| Complex::new(0.01 * ((i % 10) as f64), 0.0))
            .collect();
        let weights_pt = ev.encode(&w, ev.ctx.max_level());
        let model = ModelState { weights_pt, rot_steps: slots };
        (Arc::new(ev), enc, dec, Arc::new(model), rng)
    }

    #[test]
    fn serves_rotations_correctly() {
        let (ev, enc, dec, model, mut rng) = setup();
        let coord = Coordinator::start(
            ev.clone(),
            model,
            ServeConfig {
                fhec_workers: 2,
                cuda_workers: 1,
                max_batch: 4,
                linger: Duration::from_millis(1),
                max_queue: 64,
            },
        );
        let slots = ev.ctx.params.slots();
        let z: Vec<Complex> = (0..slots)
            .map(|i| Complex::new((i % 7) as f64 * 0.1, 0.0))
            .collect();
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        let rx = coord
            .submit(Request::new(1, OpKind::Rotate(3), ct))
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.id, 1);
        let out = resp.ct.expect("rotation key declared");
        let back = dec.decrypt_to_slots(&ev.ctx, &out);
        for j in 0..slots {
            let want = (((j + 3) % slots) % 7) as f64 * 0.1;
            assert!((back[j].re - want).abs() < 1e-3, "slot {j}");
        }
        assert!(resp.sim_base_us > resp.sim_fhec_us, "FHECore must be faster");
    }

    #[test]
    fn batches_multiple_requests() {
        let (ev, enc, dec, model, mut rng) = setup();
        let coord = Coordinator::start(
            ev.clone(),
            model,
            ServeConfig {
                fhec_workers: 2,
                cuda_workers: 1,
                max_batch: 4,
                linger: Duration::from_millis(5),
                max_queue: 64,
            },
        );
        let slots = ev.ctx.params.slots();
        let z = vec![Complex::new(0.5, 0.0); slots];
        let mut receivers = Vec::new();
        for id in 0..6u64 {
            let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
            receivers.push(coord.submit(Request::new(id, OpKind::Square, ct)).unwrap());
        }
        for rx in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            let out = resp.ct.expect("relin key declared");
            let back = dec.decrypt_to_slots(&ev.ctx, &out);
            assert!((back[0].re - 0.25).abs() < 1e-2, "0.5^2 = 0.25, got {}", back[0].re);
        }
        let m = &coord.metrics;
        assert_eq!(m.served.load(Ordering::Relaxed), 6);
        assert!(m.batches.load(Ordering::Relaxed) >= 1);
        assert!(m.mean_batch() >= 1.0);
        // All six squares are FHEC-class.
        assert_eq!(m.fhec_served.load(Ordering::Relaxed), 6);
        assert_eq!(m.cuda_served.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let (ev, enc, _dec, model, mut rng) = setup();
        // A linger window far longer than any CI scheduling hiccup + a
        // huge max_batch: nothing can be claimed while we fill the
        // window, so the third submit must bounce deterministically.
        let coord = Coordinator::start(
            ev.clone(),
            model,
            ServeConfig {
                fhec_workers: 1,
                cuda_workers: 1,
                max_batch: 100,
                linger: Duration::from_secs(60),
                max_queue: 2,
            },
        );
        let slots = ev.ctx.params.slots();
        let z = vec![Complex::new(0.1, 0.0); slots];
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        let r1 = coord.submit(Request::new(1, OpKind::Rotate(3), ct.clone()));
        let r2 = coord.submit(Request::new(2, OpKind::Rotate(3), ct.clone()));
        assert!(r1.is_ok() && r2.is_ok());
        let r3 = coord.submit(Request::new(3, OpKind::Rotate(3), ct.clone()));
        let (bounced, err) = r3.err().expect("third submit must bounce");
        assert_eq!(bounced.id, 3, "rejected request is handed back");
        assert_eq!(err, SubmitError::QueueFull { depth: 2 });
        assert_eq!(coord.metrics.rejected.load(Ordering::Relaxed), 1);
        // The bound is per lane: the CUDA lane still admits.
        let r4 = coord.submit(Request::new(4, OpKind::Add, ct.clone()).with_ct2(ct));
        assert!(r4.is_ok(), "CUDA lane has its own bound");
        assert_eq!(coord.queue_depths(), [2, 1]);
        // Dropping the coordinator drains gracefully: the open windows are
        // promoted, the workers serve them, and the joins complete — the
        // admitted three get responses without waiting out the linger.
        drop(coord);
        for rx in [r1.unwrap(), r2.unwrap(), r4.unwrap()] {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(resp.ct.is_ok());
        }
    }

    #[test]
    fn undeclared_rotation_returns_typed_error() {
        let (ev, enc, _dec, model, mut rng) = setup();
        let coord = Coordinator::start(
            ev.clone(),
            model,
            ServeConfig {
                fhec_workers: 1,
                cuda_workers: 1,
                max_batch: 1,
                linger: Duration::from_millis(1),
                max_queue: 8,
            },
        );
        let slots = ev.ctx.params.slots();
        let z = vec![Complex::new(0.1, 0.0); slots];
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        // Step 7 was never declared in the key spec.
        let rx = coord.submit(Request::new(9, OpKind::Rotate(7), ct)).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let err = resp.ct.unwrap_err();
        match err.kind {
            KeyKind::Galois(_) => {}
            other => panic!("expected Galois MissingKey, got {other:?}"),
        }
        assert_eq!(err.level, ev.ctx.max_level());
    }

    #[test]
    fn cuda_lane_serves_elementwise_ops() {
        let (ev, enc, dec, model, mut rng) = setup();
        let coord = Coordinator::start(
            ev.clone(),
            model,
            ServeConfig {
                fhec_workers: 1,
                cuda_workers: 2,
                max_batch: 2,
                linger: Duration::from_millis(1),
                max_queue: 16,
            },
        );
        let slots = ev.ctx.params.slots();
        let z = vec![Complex::new(0.2, 0.0); slots];
        let ca = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        let cb = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        let rx = coord
            .submit(Request::new(1, OpKind::Add, ca.clone()).with_ct2(cb))
            .unwrap();
        let sum = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap()
            .ct
            .expect("add is key-free");
        let back = dec.decrypt_to_slots(&ev.ctx, &sum);
        assert!((back[0].re - 0.4).abs() < 1e-3, "0.2+0.2, got {}", back[0].re);
        // Rescale rides the CUDA lane too.
        let rx = coord.submit(Request::new(2, OpKind::Rescale, ca)).unwrap();
        let low = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap()
            .ct
            .expect("rescale is key-free");
        assert_eq!(low.level, ev.ctx.max_level() - 1);
        let m = coord.snapshot();
        assert_eq!(m.cuda_served, 2);
        assert_eq!(m.fhec_served, 0);
        assert_eq!(m.served, 2);
    }

    #[test]
    fn structurally_invalid_requests_bounce_at_admission() {
        let (ev, enc, _dec, model, mut rng) = setup();
        let coord = Coordinator::start(ev.clone(), model, ServeConfig::default());
        let slots = ev.ctx.params.slots();
        let z = vec![Complex::new(0.1, 0.0); slots];
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        // Binary op without its second operand.
        let (_, err) = coord
            .submit(Request::new(1, OpKind::Mul, ct.clone()))
            .err()
            .expect("Mul without ct2 must bounce");
        assert!(matches!(err, SubmitError::BadRequest(_)));
        // HomLinear without a matrix.
        let (_, err) = coord
            .submit(Request::new(2, OpKind::HomLinear, ct.clone()))
            .err()
            .expect("HomLinear without matrix must bounce");
        assert!(matches!(err, SubmitError::BadRequest(_)));
        // Level-consuming ops with no level left.
        let bottom = ev.level_reduce(&ct, 0);
        for op in [OpKind::Rescale, OpKind::Square, OpKind::LinearScore] {
            let (_, err) = coord
                .submit(Request::new(3, op, bottom.clone()))
                .err()
                .expect("level-0 rescaling op must bounce");
            assert!(matches!(err, SubmitError::BadRequest(_)), "{op:?}");
        }
        // Matrix whose dimension disagrees with the slot count.
        let tiny = crate::ckks::linear::SlotMatrix::identity(4);
        let (_, err) = coord
            .submit(Request::new(4, OpKind::HomLinear, ct.clone()).with_matrix(tiny))
            .err()
            .expect("mis-sized matrix must bounce");
        assert!(matches!(err, SubmitError::BadRequest(_)));
        // All-zero matrix: hom_linear has no nonzero diagonal to sum.
        let zero = crate::ckks::linear::SlotMatrix::zeros(slots);
        let (_, err) = coord
            .submit(Request::new(6, OpKind::HomLinear, ct.clone()).with_matrix(zero))
            .err()
            .expect("all-zero matrix must bounce");
        assert!(matches!(err, SubmitError::BadRequest(_)));
        // Binary op whose operand scales can never align.
        let mut skewed = ct.clone();
        skewed.scale *= 8.0;
        let (_, err) = coord
            .submit(Request::new(5, OpKind::Add, ct.clone()).with_ct2(skewed))
            .err()
            .expect("scale-mismatched operands must bounce");
        assert!(matches!(err, SubmitError::BadRequest(_)));
        // Structural rejections are not backpressure.
        assert_eq!(coord.metrics.rejected.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn metrics_snapshot_absorb_sums_shards() {
        let mut a = MetricsSnapshot {
            served: 10,
            batches: 5,
            rejected: 1,
            queue_peak: 4,
            mean_service_us: 100.0,
            mean_batch: 2.0,
            fhec_depth: 2,
            cuda_depth: 1,
            fhec_served: 8,
            cuda_served: 2,
            programs: 1,
            mlt_backend: crate::ckks::mlt_backend::codes::AVX2,
            tenants_resident: 1,
            tenants_cold: 0,
            registry_hits: 5,
            registry_misses: 1,
            key_evictions: 0,
            key_expansions: 1,
            expansion_us: 100,
            resident_key_bytes: 1000,
            pool_hits: 7,
            pool_misses: 2,
            pool_bytes_hwm: 500,
            overloaded: 0,
            fused_dispatches: 3,
            fused_members: 9,
            fused_occupancy_peak: 4,
            fused_hist: [1, 1, 1, 0],
            sched_depth: 2,
            sched_rejected: 1,
            ..MetricsSnapshot::default()
        };
        let b = MetricsSnapshot {
            served: 30,
            batches: 10,
            rejected: 3,
            queue_peak: 9,
            mean_service_us: 200.0,
            mean_batch: 3.0,
            fhec_depth: 1,
            cuda_depth: 0,
            fhec_served: 25,
            cuda_served: 5,
            programs: 4,
            mlt_backend: crate::ckks::mlt_backend::codes::AVX2,
            tenants_resident: 2,
            tenants_cold: 1,
            registry_hits: 10,
            registry_misses: 2,
            key_evictions: 3,
            key_expansions: 4,
            expansion_us: 900,
            resident_key_bytes: 2000,
            pool_hits: 3,
            pool_misses: 1,
            pool_bytes_hwm: 300,
            overloaded: 2,
            fused_dispatches: 2,
            fused_members: 12,
            fused_occupancy_peak: 8,
            fused_hist: [0, 0, 1, 1],
            sched_depth: 1,
            sched_rejected: 2,
            ..MetricsSnapshot::default()
        };
        a.absorb(&b);
        assert_eq!(a.served, 40);
        assert_eq!(a.batches, 15);
        assert_eq!(a.rejected, 4);
        assert_eq!(a.queue_peak, 9);
        // Served-weighted: (10*100 + 30*200) / 40.
        assert!((a.mean_service_us - 175.0).abs() < 1e-9);
        assert!((a.mean_batch - 40.0 / 15.0).abs() < 1e-9);
        assert_eq!(a.fhec_depth, 3);
        assert_eq!(a.cuda_depth, 1);
        assert_eq!(a.fhec_served, 33);
        assert_eq!(a.cuda_served, 7);
        assert_eq!(a.programs, 5);
        assert_eq!(a.tenants_resident, 3);
        assert_eq!(a.tenants_cold, 1);
        assert_eq!(a.registry_hits, 15);
        assert_eq!(a.registry_misses, 3);
        assert_eq!(a.key_evictions, 3);
        assert_eq!(a.key_expansions, 5);
        assert_eq!(a.expansion_us, 1000);
        assert_eq!(a.resident_key_bytes, 3000);
        assert_eq!(a.pool_hits, 10);
        assert_eq!(a.pool_misses, 3);
        // The pool high-water mark is a peak: max across shards, not sum.
        assert_eq!(a.pool_bytes_hwm, 500);
        assert_eq!(a.overloaded, 2);
        assert_eq!(a.fused_dispatches, 5);
        assert_eq!(a.fused_members, 21);
        // The occupancy peak is a peak: max across shards, not sum.
        assert_eq!(a.fused_occupancy_peak, 8);
        assert_eq!(a.fused_hist, [1, 1, 2, 1]);
        assert_eq!(a.sched_depth, 3);
        assert_eq!(a.sched_rejected, 3);
        assert!((a.mean_fused_occupancy() - 21.0 / 5.0).abs() < 1e-9);
        // Matching shard backends survive aggregation unchanged.
        assert_eq!(a.mlt_backend, crate::ckks::mlt_backend::codes::AVX2);
        // Absorbing an empty (Default) snapshot is the identity on counters
        // — including the backend byte (Default = UNKNOWN never wins).
        let before = a;
        a.absorb(&MetricsSnapshot::default());
        assert_eq!(a, before);
        // A shard on a different backend flags the aggregate as mixed.
        let mut c = MetricsSnapshot {
            mlt_backend: crate::ckks::mlt_backend::codes::SCALAR,
            ..MetricsSnapshot::default()
        };
        c.absorb(&a);
        assert_eq!(c.mlt_backend, crate::ckks::mlt_backend::codes::MIXED);
        // Unknown (pre-v4) on the left adopts the known right-hand value.
        let mut d = MetricsSnapshot::default();
        d.absorb(&a);
        assert_eq!(d.mlt_backend, crate::ckks::mlt_backend::codes::AVX2);
    }

    #[test]
    fn absorb_saturates_instead_of_wrapping() {
        // A gateway summing shard counters near u64::MAX must pin, not
        // wrap: a wrapped counter looks like a healthy restart.
        let mut a = MetricsSnapshot {
            served: u64::MAX - 5,
            registry_hits: u64::MAX,
            pool_hits: u64::MAX - 1,
            tenants_resident: u32::MAX,
            ..MetricsSnapshot::default()
        };
        let b = MetricsSnapshot {
            served: 10,
            registry_hits: 3,
            pool_hits: 7,
            tenants_resident: 2,
            ..MetricsSnapshot::default()
        };
        a.absorb(&b);
        assert_eq!(a.served, u64::MAX);
        assert_eq!(a.registry_hits, u64::MAX);
        assert_eq!(a.pool_hits, u64::MAX);
        assert_eq!(a.tenants_resident, u32::MAX);
    }

    #[test]
    fn absorb_merges_telemetry_histograms_bucketwise() {
        // A gateway summing shard latency histograms must add per-bucket:
        // identical edges everywhere make the bucket sum exactly the
        // union histogram (this rides the same shared `merge_buckets`
        // helper as the occupancy histogram above).
        let mut a = MetricsSnapshot::default();
        let mut b = MetricsSnapshot::default();
        let mut union = LatencyHist::default();
        for ns in [800u64, 900, 40_000] {
            a.queue_wait_hist.record(ns);
            a.exec_hist[1].record(ns);
            a.stage_hist[Stage::Ntt as usize].record(ns);
            union.record(ns);
        }
        for ns in [1_000u64, 2_000_000] {
            b.queue_wait_hist.record(ns);
            b.exec_hist[1].record(ns);
            b.stage_hist[Stage::Ntt as usize].record(ns);
            union.record(ns);
        }
        a.stage_ns = [7; STAGE_COUNT];
        b.stage_ns = [5; STAGE_COUNT];
        a.slow_requests = 2;
        b.slow_requests = 3;
        a.trace_dropped = u64::MAX;
        b.trace_dropped = 9;
        a.work.rows[1].tile_ops = 100;
        b.work.rows[1].tile_ops = 11;
        b.work.rows[2].butterflies = 4;
        a.absorb(&b);
        assert_eq!(a.queue_wait_hist, union);
        assert_eq!(a.exec_hist[1], union);
        assert_eq!(a.exec_hist[0], LatencyHist::default());
        assert_eq!(a.stage_hist[Stage::Ntt as usize], union);
        assert_eq!(a.stage_ns, [12; STAGE_COUNT]);
        assert_eq!(a.slow_requests, 5);
        assert_eq!(a.trace_dropped, u64::MAX, "dropped count must saturate");
        assert_eq!(a.work.rows[1].tile_ops, 111);
        assert_eq!(a.work.rows[2].butterflies, 4);
        // The merged p99 is readable off the union histogram.
        assert!(a.queue_wait_hist.quantile_ns(0.99) >= 2_000_000);
    }

    #[test]
    fn op_classification() {
        assert_eq!(OpKind::Mul.class(), OpClass::Fhec);
        assert_eq!(OpKind::Square.class(), OpClass::Fhec);
        assert_eq!(OpKind::Rotate(1).class(), OpClass::Fhec);
        assert_eq!(OpKind::Conjugate.class(), OpClass::Fhec);
        assert_eq!(OpKind::LinearScore.class(), OpClass::Fhec);
        assert_eq!(OpKind::HomLinear.class(), OpClass::Fhec);
        assert_eq!(OpKind::Add.class(), OpClass::Cuda);
        assert_eq!(OpKind::Rescale.class(), OpClass::Cuda);
        // The wire/local op-gap closers are all key-free -> CUDA lane.
        assert_eq!(OpKind::Sub.class(), OpClass::Cuda);
        assert_eq!(OpKind::Negate.class(), OpClass::Cuda);
        assert_eq!(OpKind::MulConst(2.0).class(), OpClass::Cuda);
        assert_eq!(OpKind::AddConst(1.0).class(), OpClass::Cuda);
        assert_eq!(OpKind::MulPlain.class(), OpClass::Cuda);
        assert_eq!(OpKind::LevelReduce(1).class(), OpClass::Cuda);
        assert!(OpKind::Mul.needs_ct2() && OpKind::Add.needs_ct2() && OpKind::Sub.needs_ct2());
        assert!(!OpKind::Square.needs_ct2());
        assert!(OpKind::HomLinear.needs_matrix());
        assert!(OpKind::MulPlain.needs_pt() && !OpKind::Add.needs_pt());
        assert!(OpKind::MulConst(2.0).consumes_level());
        assert!(OpKind::MulPlain.consumes_level());
        assert!(!OpKind::AddConst(1.0).consumes_level());
        assert!(!OpKind::LevelReduce(0).consumes_level());
    }

    #[test]
    fn extended_ops_serve_on_the_cuda_lane() {
        let (ev, enc, dec, model, mut rng) = setup();
        let coord = Coordinator::start(ev.clone(), model, ServeConfig::default());
        let slots = ev.ctx.params.slots();
        let z: Vec<Complex> = (0..slots)
            .map(|i| Complex::new(0.1 * (i % 4) as f64, 0.0))
            .collect();
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        let ct2 = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        let pt = ev.encode(&vec![Complex::new(2.0, 0.0); slots], ev.ctx.max_level());
        let cases: Vec<(Request, Box<dyn Fn(&Ciphertext) -> Ciphertext>)> = vec![
            (
                Request::new(1, OpKind::Sub, ct.clone()).with_ct2(ct2.clone()),
                Box::new({
                    let (ev, ct2) = (ev.clone(), ct2.clone());
                    move |c: &Ciphertext| ev.sub(c, &ct2)
                }),
            ),
            (
                Request::new(2, OpKind::Negate, ct.clone()),
                Box::new({
                    let ev = ev.clone();
                    move |c: &Ciphertext| ev.negate(c)
                }),
            ),
            (
                Request::new(3, OpKind::MulConst(2.0), ct.clone()),
                Box::new({
                    let ev = ev.clone();
                    move |c: &Ciphertext| ev.mul_const(c, 2.0)
                }),
            ),
            (
                Request::new(4, OpKind::AddConst(0.5), ct.clone()),
                Box::new({
                    let ev = ev.clone();
                    move |c: &Ciphertext| ev.add_const(c, 0.5)
                }),
            ),
            (
                Request::new(5, OpKind::MulPlain, ct.clone()).with_pt(pt.clone()),
                Box::new({
                    let (ev, pt) = (ev.clone(), pt.clone());
                    move |c: &Ciphertext| ev.mul_plain(c, &pt)
                }),
            ),
            (
                Request::new(6, OpKind::LevelReduce(1), ct.clone()),
                Box::new({
                    let ev = ev.clone();
                    move |c: &Ciphertext| ev.level_reduce(c, 1)
                }),
            ),
        ];
        let n_cases = cases.len() as u64;
        for (req, reference) in cases {
            let id = req.id;
            let rx = coord.submit(req).unwrap_or_else(|(_, e)| panic!("op {id}: {e}"));
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            let got = resp.ct.expect("all extended ops are key-free");
            assert_eq!(got, reference(&ct), "op {id} must match the local evaluator");
        }
        let snap = coord.snapshot();
        assert_eq!(snap.cuda_served, n_cases, "all extended ops ride the CUDA lane");
        assert_eq!(snap.fhec_served, 0);
        // Structural rejections: missing pt, bad level-reduce target.
        let (_, err) = coord
            .submit(Request::new(9, OpKind::MulPlain, ct.clone()))
            .err()
            .expect("MulPlain without pt must bounce");
        assert!(matches!(err, SubmitError::BadRequest(_)));
        let (_, err) = coord
            .submit(Request::new(10, OpKind::LevelReduce(9), ct.clone()))
            .err()
            .expect("level_reduce above the operand level must bounce");
        assert!(matches!(err, SubmitError::BadRequest(_)));
        let _ = dec;
    }

    #[test]
    fn program_requests_route_and_execute_as_one_batch() {
        use crate::ckks::ProgramBuilder;
        let (ev, enc, dec, model, mut rng) = setup();
        let coord = Coordinator::start(ev.clone(), model, ServeConfig::default());
        let slots = ev.ctx.params.slots();
        let z: Vec<Complex> = (0..slots)
            .map(|i| Complex::new(0.05 * (i % 6) as f64, 0.0))
            .collect();
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);

        // Square then a rotation fan-out, summed — FHEC-class program.
        let mut b = ProgramBuilder::new();
        let x = b.input("x");
        let sq = b.square(x);
        let r1 = b.rotate(sq, 1);
        let r3 = b.rotate(sq, 3);
        let y = b.add(r1, r3);
        b.output("y", y);
        let prog = Arc::new(b.finish());

        let rx = coord
            .submit_program(ProgramRequest::new(7, prog.clone(), vec![ct.clone()]))
            .unwrap_or_else(|(_, e)| panic!("program admission: {e}"));
        let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
        assert_eq!(resp.id, 7);
        let outs = resp.outputs.expect("declared keys cover the program");
        assert_eq!(outs.len(), 1);
        // Bit-identical to running the same program locally.
        let want = ev.run_program(&prog, std::slice::from_ref(&ct)).unwrap();
        assert_eq!(outs, want);
        assert!(resp.sim_base_us > resp.sim_fhec_us, "FHECore must be faster");
        let snap = coord.snapshot();
        assert_eq!(snap.programs, 1);
        assert_eq!(snap.fhec_served, 1, "key-switching program rides the FHEC lane");

        // An invalid program (undeclared rotation) bounces at admission,
        // typed.
        let mut b = ProgramBuilder::new();
        let x = b.input("x");
        let r = b.rotate(x, 7);
        b.output("y", r);
        let bad = Arc::new(b.finish());
        let (_, err) = coord
            .submit_program(ProgramRequest::new(8, bad, vec![ct]))
            .err()
            .expect("undeclared rotation must bounce at admission");
        assert!(
            matches!(
                err,
                ProgramSubmitError::Invalid(crate::ckks::ProgramError::MissingKey { .. })
            ),
            "{err:?}"
        );

        let back = dec.decrypt_to_slots(&ev.ctx, &outs[0]);
        for j in 0..slots {
            let f = |k: usize| {
                let v = 0.05 * (((j + k) % slots) % 6) as f64;
                v * v
            };
            assert!((back[j].re - (f(1) + f(3))).abs() < 1e-2, "slot {j}");
        }
    }
}
