//! L3 coordinator: the encrypted-inference serving loop.
//!
//! This is the deployment shell around the paper's system: clients submit
//! ciphertexts, the coordinator batches them, workers execute the
//! homomorphic compute through the CKKS substrate, and every batch is
//! *dually dispatched* — functionally (real ciphertext math, optionally
//! through the PJRT FHECore artifacts) and to the timing model (gpusim),
//! so each response carries both the real result and the simulated
//! A100/A100+FHECore latency for that batch's op mix.
//!
//! Built on std threads + channels (tokio is not vendored in this offline
//! build; the architecture is the same: a bounded submit queue, a batcher
//! with a linger window, and a worker pool).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::ckks::{Ciphertext, Evaluator, RnsPoly, SecretKey};
use crate::codegen::{Backend, Compiler, SimParams};
use crate::gpusim::{simulate_trace, GpuConfig};
use crate::isa::Trace;

/// The homomorphic op sequences a request can ask for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// dot(w, x) + b via rotate-and-sum — encrypted linear scoring.
    LinearScore,
    /// One ciphertext-ciphertext product (with relinearization).
    Square,
    /// Slot rotation by k.
    Rotate(usize),
}

pub struct Request {
    pub id: u64,
    pub op: OpKind,
    pub ct: Ciphertext,
}

pub struct Response {
    pub id: u64,
    pub ct: Ciphertext,
    /// Wall-clock service time of the functional path.
    pub service: Duration,
    /// Simulated A100 / A100+FHECore latency for this request's op mix.
    pub sim_base_us: f64,
    pub sim_fhec_us: f64,
    pub batch_size: usize,
}

/// Shared server-side model state (plaintext weights etc.).
pub struct ModelState {
    pub weights_pt: RnsPoly,
    pub rot_steps: usize,
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub linger: Duration,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self { workers: 2, max_batch: 8, linger: Duration::from_millis(2) }
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub queue_peak: AtomicUsize,
    pub total_service_us: AtomicU64,
}

impl Metrics {
    pub fn mean_service_us(&self) -> f64 {
        let n = self.served.load(Ordering::Relaxed).max(1);
        self.total_service_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.served.load(Ordering::Relaxed) as f64 / b as f64
    }
}

/// The coordinator: submit() requests, receive Responses on the channel
/// handed to `start`.
pub struct Coordinator {
    tx: Sender<(Request, Sender<Response>)>,
    pub metrics: Arc<Metrics>,
}

impl Coordinator {
    /// Spawn batcher + workers. `ev`/`sk`/`model` are shared read-only.
    pub fn start(
        ev: Arc<Evaluator>,
        sk: Arc<SecretKey>,
        model: Arc<ModelState>,
        cfg: ServeConfig,
    ) -> Self {
        let (tx, rx) = channel::<(Request, Sender<Response>)>();
        let metrics = Arc::new(Metrics::default());
        let m = metrics.clone();
        std::thread::spawn(move || batcher_loop(rx, ev, sk, model, cfg, m));
        Self { tx, metrics }
    }

    pub fn submit(&self, req: Request) -> Receiver<Response> {
        let (rtx, rrx) = channel();
        self.tx.send((req, rtx)).expect("coordinator stopped");
        rrx
    }
}

fn batcher_loop(
    rx: Receiver<(Request, Sender<Response>)>,
    ev: Arc<Evaluator>,
    sk: Arc<SecretKey>,
    model: Arc<ModelState>,
    cfg: ServeConfig,
    metrics: Arc<Metrics>,
) {
    // Worker pool fed by a shared batch queue.
    let batch_q: Arc<Mutex<Vec<Vec<(Request, Sender<Response>)>>>> =
        Arc::new(Mutex::new(Vec::new()));
    for _ in 0..cfg.workers.max(1) {
        let q = batch_q.clone();
        let ev = ev.clone();
        let sk = sk.clone();
        let model = model.clone();
        let metrics = metrics.clone();
        std::thread::spawn(move || loop {
            let batch = { q.lock().unwrap().pop() };
            match batch {
                Some(batch) => serve_batch(batch, &ev, &sk, &model, &metrics),
                None => std::thread::sleep(Duration::from_micros(200)),
            }
        });
    }

    // Linger-window batching.
    let mut pending: Vec<(Request, Sender<Response>)> = Vec::new();
    let mut window_start = Instant::now();
    loop {
        let timeout = cfg
            .linger
            .checked_sub(window_start.elapsed())
            .unwrap_or(Duration::ZERO);
        match rx.recv_timeout(if pending.is_empty() {
            Duration::from_millis(50)
        } else {
            timeout
        }) {
            Ok(item) => {
                if pending.is_empty() {
                    window_start = Instant::now();
                }
                pending.push(item);
                let depth = pending.len();
                metrics.queue_peak.fetch_max(depth, Ordering::Relaxed);
                if depth >= cfg.max_batch {
                    batch_q.lock().unwrap().push(std::mem::take(&mut pending));
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {
                if !pending.is_empty() {
                    batch_q.lock().unwrap().push(std::mem::take(&mut pending));
                }
            }
            Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                if !pending.is_empty() {
                    batch_q.lock().unwrap().push(std::mem::take(&mut pending));
                }
                return;
            }
        }
    }
}

/// Build the timing-model trace for one request's op mix.
fn request_trace(op: OpKind, level: usize, ev: &Evaluator, backend: Backend) -> Trace {
    let p = SimParams {
        n: ev.ctx.params.n.max(256),
        l: level + 1,
        alpha: ev.ctx.p_chain.len().max(1),
        dnum: ev.ctx.params.dnum,
    };
    let c = Compiler::new(backend);
    match op {
        OpKind::LinearScore => {
            let mut t = c.ptmult(&p);
            let rot_steps = (ev.ctx.params.slots() as f64).log2().ceil() as usize;
            for _ in 0..rot_steps {
                t.extend(c.rotate(&p));
                t.extend(c.headd(&p));
            }
            t
        }
        OpKind::Square => c.hemult(&p),
        OpKind::Rotate(_) => c.rotate(&p),
    }
}

fn serve_batch(
    batch: Vec<(Request, Sender<Response>)>,
    ev: &Evaluator,
    sk: &SecretKey,
    model: &ModelState,
    metrics: &Metrics,
) {
    let gpu = GpuConfig::default();
    let n = batch.len();
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    for (req, reply) in batch {
        let t0 = Instant::now();
        let out = match req.op {
            OpKind::LinearScore => {
                // dot(w, x): PtMult then rotate-and-sum over all slots.
                let mut acc = ev.mul_plain(&req.ct, &model.weights_pt);
                let mut step = 1usize;
                while step < model.rot_steps {
                    let rot = ev.rotate(&acc, step, sk);
                    acc = ev.add(&acc, &rot);
                    step <<= 1;
                }
                acc
            }
            OpKind::Square => ev.mul(&req.ct, &req.ct, sk),
            OpKind::Rotate(k) => ev.rotate(&req.ct, k, sk),
        };
        let service = t0.elapsed();
        // Dual dispatch: the timing model for this op mix.
        let base = request_trace(req.op, out.level, ev, Backend::A100);
        let fhec = request_trace(req.op, out.level, ev, Backend::A100Fhec);
        let sim_base_us = simulate_trace(&gpu, &base).latency_us(&gpu);
        let sim_fhec_us = simulate_trace(&gpu, &fhec).latency_us(&gpu);
        metrics.served.fetch_add(1, Ordering::Relaxed);
        metrics
            .total_service_us
            .fetch_add(service.as_micros() as u64, Ordering::Relaxed);
        let _ = reply.send(Response {
            id: req.id,
            ct: out,
            service,
            sim_base_us,
            sim_fhec_us,
            batch_size: n,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Complex;
    use crate::ckks::params::{CkksContext, CkksParams};
    use crate::util::rng::Pcg64;

    fn setup() -> (Arc<Evaluator>, Arc<SecretKey>, Arc<ModelState>, Pcg64) {
        let ctx = CkksContext::new(CkksParams::toy());
        let mut rng = Pcg64::new(0x5EEE);
        let sk = SecretKey::generate(&ctx, &mut rng);
        let ev = Evaluator::new(ctx);
        let slots = ev.ctx.params.slots();
        let w: Vec<Complex> = (0..slots)
            .map(|i| Complex::new(0.01 * ((i % 10) as f64), 0.0))
            .collect();
        let weights_pt = ev.encode(&w, ev.ctx.max_level());
        let model = ModelState { weights_pt, rot_steps: slots };
        (Arc::new(ev), Arc::new(sk), Arc::new(model), rng)
    }

    #[test]
    fn serves_rotations_correctly() {
        let (ev, sk, model, mut rng) = setup();
        let coord = Coordinator::start(
            ev.clone(),
            sk.clone(),
            model,
            ServeConfig { workers: 2, max_batch: 4, linger: Duration::from_millis(1) },
        );
        let slots = ev.ctx.params.slots();
        let z: Vec<Complex> = (0..slots)
            .map(|i| Complex::new((i % 7) as f64 * 0.1, 0.0))
            .collect();
        let ct = ev.encrypt(&ev.encode(&z, ev.ctx.max_level()), &sk, &mut rng);
        let rx = coord.submit(Request { id: 1, op: OpKind::Rotate(3), ct });
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.id, 1);
        let back = ev.decrypt_to_slots(&resp.ct, &sk);
        for j in 0..slots {
            let want = (((j + 3) % slots) % 7) as f64 * 0.1;
            assert!((back[j].re - want).abs() < 1e-3, "slot {j}");
        }
        assert!(resp.sim_base_us > resp.sim_fhec_us, "FHECore must be faster");
    }

    #[test]
    fn batches_multiple_requests() {
        let (ev, sk, model, mut rng) = setup();
        let coord = Coordinator::start(
            ev.clone(),
            sk.clone(),
            model,
            ServeConfig { workers: 2, max_batch: 4, linger: Duration::from_millis(5) },
        );
        let slots = ev.ctx.params.slots();
        let z = vec![Complex::new(0.5, 0.0); slots];
        let mut receivers = Vec::new();
        for id in 0..6u64 {
            let ct = ev.encrypt(&ev.encode(&z, ev.ctx.max_level()), &sk, &mut rng);
            receivers.push(coord.submit(Request { id, op: OpKind::Square, ct }));
        }
        for rx in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            let back = ev.decrypt_to_slots(&resp.ct, &sk);
            assert!((back[0].re - 0.25).abs() < 1e-2, "0.5^2 = 0.25, got {}", back[0].re);
        }
        let m = &coord.metrics;
        assert_eq!(m.served.load(Ordering::Relaxed), 6);
        assert!(m.batches.load(Ordering::Relaxed) >= 1);
        assert!(m.mean_batch() >= 1.0);
    }
}
