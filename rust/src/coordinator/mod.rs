//! L3 coordinator: the encrypted-inference serving loop.
//!
//! This is the deployment shell around the paper's system: clients submit
//! ciphertexts, the coordinator batches them, workers execute the
//! homomorphic compute through the CKKS substrate, and every batch is
//! *dually dispatched* — functionally (real ciphertext math, optionally
//! through the PJRT FHECore artifacts) and to the timing model (gpusim),
//! so each response carries both the real result and the simulated
//! A100/A100+FHECore latency for that batch's op mix.
//!
//! **Workers hold no secret material.** They are constructed from an
//! `Arc<Evaluator>` whose only key state is the shared public
//! `Arc<EvalKeySet>`; an op whose key the client never declared comes
//! back as a typed [`MissingKey`] in the response instead of being
//! silently derived server-side.
//!
//! Built on std threads + a Condvar-signalled batch queue (tokio is not
//! vendored in this offline build; the architecture is the same): submit
//! is *bounded* — beyond `ServeConfig::max_queue` in-flight requests it
//! rejects with [`SubmitError::QueueFull`] (backpressure) — a linger
//! window accumulates batches, and whichever worker wakes first flushes
//! the window. No thread ever sleep-polls.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::ckks::{Ciphertext, Evaluator, MissingKey, RnsPoly};
use crate::codegen::{Backend, Compiler, SimParams};
use crate::gpusim::{simulate_trace, GpuConfig};
use crate::isa::Trace;

/// The homomorphic op sequences a request can ask for.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OpKind {
    /// dot(w, x) + b via rotate-and-sum — encrypted linear scoring.
    LinearScore,
    /// One ciphertext-ciphertext product (with relinearization).
    Square,
    /// Slot rotation by k.
    Rotate(usize),
}

#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub op: OpKind,
    pub ct: Ciphertext,
}

pub struct Response {
    pub id: u64,
    /// The homomorphic result — or the typed failure when the public key
    /// set lacks a key the op needs.
    pub ct: Result<Ciphertext, MissingKey>,
    /// Wall-clock service time of the functional path.
    pub service: Duration,
    /// Simulated A100 / A100+FHECore latency for this request's op mix.
    pub sim_base_us: f64,
    pub sim_fhec_us: f64,
    pub batch_size: usize,
}

/// Shared server-side model state (plaintext weights etc.).
pub struct ModelState {
    pub weights_pt: RnsPoly,
    pub rot_steps: usize,
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub workers: usize,
    pub max_batch: usize,
    pub linger: Duration,
    /// Bound on admitted-but-unclaimed requests (pending window + queued
    /// batches). `submit` rejects beyond this — backpressure, not OOM.
    pub max_queue: usize,
}

impl Default for ServeConfig {
    fn default() -> Self {
        Self {
            workers: 2,
            max_batch: 8,
            linger: Duration::from_millis(2),
            max_queue: 64,
        }
    }
}

#[derive(Debug, Default)]
pub struct Metrics {
    pub served: AtomicU64,
    pub batches: AtomicU64,
    pub queue_peak: AtomicUsize,
    pub total_service_us: AtomicU64,
    /// Submissions rejected by backpressure.
    pub rejected: AtomicU64,
}

impl Metrics {
    pub fn mean_service_us(&self) -> f64 {
        let n = self.served.load(Ordering::Relaxed).max(1);
        self.total_service_us.load(Ordering::Relaxed) as f64 / n as f64
    }

    pub fn mean_batch(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed).max(1);
        self.served.load(Ordering::Relaxed) as f64 / b as f64
    }
}

/// Why a submission was not admitted.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SubmitError {
    /// The bounded queue is at `max_queue` — shed load or retry later.
    QueueFull { depth: usize },
    /// The coordinator is shutting down.
    Stopped,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::QueueFull { depth } => {
                write!(f, "serving queue full ({depth} in flight)")
            }
            SubmitError::Stopped => write!(f, "coordinator stopped"),
        }
    }
}

impl std::error::Error for SubmitError {}

type Item = (Request, Sender<Response>);

struct QueueState {
    /// The open linger window.
    pending: Vec<Item>,
    window_start: Instant,
    /// Batches ready for a worker.
    batches: VecDeque<Vec<Item>>,
    /// pending.len() + sum of queued batch sizes (the bounded quantity).
    depth: usize,
    shutdown: bool,
}

struct Shared {
    state: Mutex<QueueState>,
    cv: Condvar,
}

/// The coordinator: `submit()` requests, receive [`Response`]s on the
/// returned channel. Dropping it drains queued batches and joins the
/// worker threads.
pub struct Coordinator {
    shared: Arc<Shared>,
    pub metrics: Arc<Metrics>,
    cfg: ServeConfig,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Spawn the worker pool. `ev` (context + public `EvalKeySet`) and
    /// `model` are shared read-only; no secret key is ever handed over.
    pub fn start(ev: Arc<Evaluator>, model: Arc<ModelState>, cfg: ServeConfig) -> Self {
        let shared = Arc::new(Shared {
            state: Mutex::new(QueueState {
                pending: Vec::new(),
                window_start: Instant::now(),
                batches: VecDeque::new(),
                depth: 0,
                shutdown: false,
            }),
            cv: Condvar::new(),
        });
        let metrics = Arc::new(Metrics::default());
        let mut workers = Vec::new();
        for _ in 0..cfg.workers.max(1) {
            let shared = shared.clone();
            let ev = ev.clone();
            let model = model.clone();
            let metrics = metrics.clone();
            let cfg = cfg.clone();
            workers.push(std::thread::spawn(move || {
                worker_loop(&shared, &ev, &model, &cfg, &metrics)
            }));
        }
        Self {
            shared,
            metrics,
            cfg,
            workers,
        }
    }

    /// Admit a request into the bounded queue. Returns the response
    /// channel, or — with [`SubmitError::QueueFull`] when `max_queue`
    /// requests are already in flight — hands the request back so the
    /// caller can shed or retry it.
    pub fn submit(&self, req: Request) -> Result<Receiver<Response>, (Request, SubmitError)> {
        let (rtx, rrx) = channel();
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            return Err((req, SubmitError::Stopped));
        }
        if st.depth >= self.cfg.max_queue {
            self.metrics.rejected.fetch_add(1, Ordering::Relaxed);
            return Err((req, SubmitError::QueueFull { depth: st.depth }));
        }
        if st.pending.is_empty() {
            st.window_start = Instant::now();
        }
        st.pending.push((req, rtx));
        st.depth += 1;
        self.metrics.queue_peak.fetch_max(st.depth, Ordering::Relaxed);
        if st.pending.len() >= self.cfg.max_batch {
            let batch = std::mem::take(&mut st.pending);
            st.batches.push_back(batch);
        }
        drop(st);
        // One worker suffices: it either claims a promoted batch or
        // becomes the timed waiter that flushes the linger window.
        // (notify_all here would stampede every idle worker per request.)
        self.shared.cv.notify_one();
        Ok(rrx)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            // Graceful drain: promote the open window so nothing admitted
            // is silently dropped.
            if !st.pending.is_empty() {
                let batch = std::mem::take(&mut st.pending);
                st.batches.push_back(batch);
            }
        }
        self.shared.cv.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim the next batch: a full/queued one immediately, the open linger
/// window once it ages past `linger`, or `None` on shutdown with an empty
/// queue. Blocks on the condvar — no sleep-polling.
fn claim_batch(shared: &Shared, cfg: &ServeConfig) -> Option<Vec<Item>> {
    let mut st = shared.state.lock().unwrap();
    loop {
        if let Some(b) = st.batches.pop_front() {
            st.depth -= b.len();
            return Some(b);
        }
        if !st.pending.is_empty() {
            let elapsed = st.window_start.elapsed();
            if elapsed >= cfg.linger {
                let batch = std::mem::take(&mut st.pending);
                st.depth -= batch.len();
                return Some(batch);
            }
            // Sleep exactly until the window closes (or new work arrives).
            let (guard, _) = shared.cv.wait_timeout(st, cfg.linger - elapsed).unwrap();
            st = guard;
            continue;
        }
        if st.shutdown {
            return None;
        }
        st = shared.cv.wait(st).unwrap();
    }
}

fn worker_loop(
    shared: &Shared,
    ev: &Evaluator,
    model: &ModelState,
    cfg: &ServeConfig,
    metrics: &Metrics,
) {
    while let Some(batch) = claim_batch(shared, cfg) {
        serve_batch(batch, ev, model, metrics);
    }
}

/// Build the timing-model trace for one request's op mix.
fn request_trace(op: OpKind, level: usize, ev: &Evaluator, backend: Backend) -> Trace {
    let p = SimParams {
        n: ev.ctx.params.n.max(256),
        l: level + 1,
        alpha: ev.ctx.p_chain.len().max(1),
        dnum: ev.ctx.params.dnum,
    };
    let c = Compiler::new(backend);
    match op {
        OpKind::LinearScore => {
            let mut t = c.ptmult(&p);
            let rot_steps = (ev.ctx.params.slots() as f64).log2().ceil() as usize;
            for _ in 0..rot_steps {
                t.extend(c.rotate(&p));
                t.extend(c.headd(&p));
            }
            t
        }
        OpKind::Square => c.hemult(&p),
        OpKind::Rotate(_) => c.rotate(&p),
    }
}

/// Execute one request against the public key set.
fn execute(ev: &Evaluator, model: &ModelState, req: &Request) -> Result<Ciphertext, MissingKey> {
    match req.op {
        OpKind::LinearScore => {
            // dot(w, x): PtMult then rotate-and-sum over all slots.
            let mut acc = ev.mul_plain(&req.ct, &model.weights_pt);
            let mut step = 1usize;
            while step < model.rot_steps {
                let rot = ev.rotate(&acc, step)?;
                acc = ev.add(&acc, &rot);
                step <<= 1;
            }
            Ok(acc)
        }
        OpKind::Square => ev.mul(&req.ct, &req.ct),
        OpKind::Rotate(k) => ev.rotate(&req.ct, k),
    }
}

fn serve_batch(batch: Vec<Item>, ev: &Evaluator, model: &ModelState, metrics: &Metrics) {
    let gpu = GpuConfig::default();
    let n = batch.len();
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    for (req, reply) in batch {
        let t0 = Instant::now();
        let out = execute(ev, model, &req);
        let service = t0.elapsed();
        // Dual dispatch: the timing model for this op mix.
        let level = out.as_ref().map(|c| c.level).unwrap_or(req.ct.level);
        let base = request_trace(req.op, level, ev, Backend::A100);
        let fhec = request_trace(req.op, level, ev, Backend::A100Fhec);
        let sim_base_us = simulate_trace(&gpu, &base).latency_us(&gpu);
        let sim_fhec_us = simulate_trace(&gpu, &fhec).latency_us(&gpu);
        metrics.served.fetch_add(1, Ordering::Relaxed);
        metrics
            .total_service_us
            .fetch_add(service.as_micros() as u64, Ordering::Relaxed);
        let _ = reply.send(Response {
            id: req.id,
            ct: out,
            service,
            sim_base_us,
            sim_fhec_us,
            batch_size: n,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckks::encoding::Complex;
    use crate::ckks::params::{CkksContext, CkksParams};
    use crate::ckks::{Decryptor, Encryptor, EvalKeySpec, KeyGen, KeyKind};
    use crate::util::rng::Pcg64;

    fn setup() -> (Arc<Evaluator>, Encryptor, Decryptor, Arc<ModelState>, Pcg64) {
        let ctx = CkksContext::new(CkksParams::toy());
        let mut rng = Pcg64::new(0x5EEE);
        let kg = KeyGen::new(&ctx, &mut rng);
        let slots = ctx.params.slots();
        // Serving kit + the explicit step the Rotate(3) test uses.
        let spec = EvalKeySpec::serving(slots).with_rotations(&[3]);
        let keys = kg.eval_key_set(&ctx, &spec, &mut rng);
        let enc = kg.encryptor();
        let dec = kg.decryptor();
        let ev = Evaluator::new(ctx, Arc::new(keys));
        let w: Vec<Complex> = (0..slots)
            .map(|i| Complex::new(0.01 * ((i % 10) as f64), 0.0))
            .collect();
        let weights_pt = ev.encode(&w, ev.ctx.max_level());
        let model = ModelState { weights_pt, rot_steps: slots };
        (Arc::new(ev), enc, dec, Arc::new(model), rng)
    }

    #[test]
    fn serves_rotations_correctly() {
        let (ev, enc, dec, model, mut rng) = setup();
        let coord = Coordinator::start(
            ev.clone(),
            model,
            ServeConfig {
                workers: 2,
                max_batch: 4,
                linger: Duration::from_millis(1),
                max_queue: 64,
            },
        );
        let slots = ev.ctx.params.slots();
        let z: Vec<Complex> = (0..slots)
            .map(|i| Complex::new((i % 7) as f64 * 0.1, 0.0))
            .collect();
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        let rx = coord
            .submit(Request { id: 1, op: OpKind::Rotate(3), ct })
            .unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.id, 1);
        let out = resp.ct.expect("rotation key declared");
        let back = dec.decrypt_to_slots(&ev.ctx, &out);
        for j in 0..slots {
            let want = (((j + 3) % slots) % 7) as f64 * 0.1;
            assert!((back[j].re - want).abs() < 1e-3, "slot {j}");
        }
        assert!(resp.sim_base_us > resp.sim_fhec_us, "FHECore must be faster");
    }

    #[test]
    fn batches_multiple_requests() {
        let (ev, enc, dec, model, mut rng) = setup();
        let coord = Coordinator::start(
            ev.clone(),
            model,
            ServeConfig {
                workers: 2,
                max_batch: 4,
                linger: Duration::from_millis(5),
                max_queue: 64,
            },
        );
        let slots = ev.ctx.params.slots();
        let z = vec![Complex::new(0.5, 0.0); slots];
        let mut receivers = Vec::new();
        for id in 0..6u64 {
            let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
            receivers.push(coord.submit(Request { id, op: OpKind::Square, ct }).unwrap());
        }
        for rx in receivers {
            let resp = rx.recv_timeout(Duration::from_secs(120)).unwrap();
            let out = resp.ct.expect("relin key declared");
            let back = dec.decrypt_to_slots(&ev.ctx, &out);
            assert!((back[0].re - 0.25).abs() < 1e-2, "0.5^2 = 0.25, got {}", back[0].re);
        }
        let m = &coord.metrics;
        assert_eq!(m.served.load(Ordering::Relaxed), 6);
        assert!(m.batches.load(Ordering::Relaxed) >= 1);
        assert!(m.mean_batch() >= 1.0);
    }

    #[test]
    fn bounded_queue_rejects_when_full() {
        let (ev, enc, _dec, model, mut rng) = setup();
        // A linger window far longer than any CI scheduling hiccup + a
        // huge max_batch: nothing can be claimed while we fill the
        // window, so the third submit must bounce deterministically.
        let coord = Coordinator::start(
            ev.clone(),
            model,
            ServeConfig {
                workers: 1,
                max_batch: 100,
                linger: Duration::from_secs(60),
                max_queue: 2,
            },
        );
        let slots = ev.ctx.params.slots();
        let z = vec![Complex::new(0.1, 0.0); slots];
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        let r1 = coord.submit(Request { id: 1, op: OpKind::Rotate(3), ct: ct.clone() });
        let r2 = coord.submit(Request { id: 2, op: OpKind::Rotate(3), ct: ct.clone() });
        assert!(r1.is_ok() && r2.is_ok());
        let r3 = coord.submit(Request { id: 3, op: OpKind::Rotate(3), ct });
        let (bounced, err) = r3.err().expect("third submit must bounce");
        assert_eq!(bounced.id, 3, "rejected request is handed back");
        assert_eq!(err, SubmitError::QueueFull { depth: 2 });
        assert_eq!(coord.metrics.rejected.load(Ordering::Relaxed), 1);
        // Dropping the coordinator drains gracefully: the open window is
        // promoted, the worker serves it, and the join completes — the
        // admitted two get responses without waiting out the linger.
        drop(coord);
        for rx in [r1.unwrap(), r2.unwrap()] {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert!(resp.ct.is_ok());
        }
    }

    #[test]
    fn undeclared_rotation_returns_typed_error() {
        let (ev, enc, _dec, model, mut rng) = setup();
        let coord = Coordinator::start(
            ev.clone(),
            model,
            ServeConfig {
                workers: 1,
                max_batch: 1,
                linger: Duration::from_millis(1),
                max_queue: 8,
            },
        );
        let slots = ev.ctx.params.slots();
        let z = vec![Complex::new(0.1, 0.0); slots];
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        // Step 7 was never declared in the key spec.
        let rx = coord.submit(Request { id: 9, op: OpKind::Rotate(7), ct }).unwrap();
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let err = resp.ct.unwrap_err();
        match err.kind {
            KeyKind::Galois(_) => {}
            other => panic!("expected Galois MissingKey, got {other:?}"),
        }
        assert_eq!(err.level, ev.ctx.max_level());
    }
}
