//! Encrypted-aggregation / PIR-style lookup over BFV (wire v8).
//!
//! The shape: a data owner uploads an **encrypted table** — one BFV
//! ciphertext whose `n` slots hold the table entries mod `t`. A querying
//! client encrypts a **one-hot selector** over the same slot layout and
//! asks the server for the dot product. The server — holding only public
//! evaluation keys — computes
//!
//! ```text
//! acc = selector * table            (exact BEHZ multiply)
//! acc += swap_rows(acc)             (fold the two batching rows)
//! acc += rotate(acc, k)  for k = 1, 2, ..., n/4   (rotate-and-sum)
//! ```
//!
//! after which **every** slot holds `table[index]` and the client
//! decrypts any one of them. The server never learns the index (it is
//! encrypted) nor the table values (they are encrypted too): this is the
//! aggregation kernel of index-private retrieval, running entirely on
//! ops a BFV engine admits over the wire (`BfvMul`, `Rotate`,
//! `Conjugate`, `Add`) — so the same query runs against a local
//! [`BfvEvaluator`], a single `fhecore-serve` node, or a sharded cluster
//! behind the gateway, bit-identically.
//!
//! Everything is exact: the returned slot equals
//! [`pir_reference`] — integer equality mod `t`, no tolerance.

use crate::bfv::{BfvContext, BfvEncryptor, BfvEvaluator};
use crate::ckks::{Ciphertext, MissingKey};
use crate::util::rng::Pcg64;
use crate::wire::{RemoteEvaluator, WireError};

/// The op surface the rotate-and-sum lookup needs — implemented by the
/// local [`BfvEvaluator`] and the wire [`RemoteEvaluator`], so one
/// lookup routine serves both the reference path and the cluster path.
pub trait PirEngine {
    type Error: std::fmt::Debug;
    /// Exact slot-wise product (BEHZ multiply + relinearization).
    fn pir_mul(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext, Self::Error>;
    /// Exact slot-wise sum.
    fn pir_add(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext, Self::Error>;
    /// Rotate both batching rows left by `k` columns.
    fn pir_rotate(&self, a: &Ciphertext, k: usize) -> Result<Ciphertext, Self::Error>;
    /// Swap the two batching rows.
    fn pir_swap_rows(&self, a: &Ciphertext) -> Result<Ciphertext, Self::Error>;
}

impl PirEngine for BfvEvaluator {
    type Error = MissingKey;
    fn pir_mul(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext, MissingKey> {
        self.mul(a, b)
    }
    fn pir_add(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext, MissingKey> {
        Ok(self.add(a, b))
    }
    fn pir_rotate(&self, a: &Ciphertext, k: usize) -> Result<Ciphertext, MissingKey> {
        self.rotate_rows(a, k)
    }
    fn pir_swap_rows(&self, a: &Ciphertext) -> Result<Ciphertext, MissingKey> {
        self.swap_rows(a)
    }
}

impl PirEngine for RemoteEvaluator {
    type Error = WireError;
    fn pir_mul(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext, WireError> {
        self.bfv_mul(a, b)
    }
    fn pir_add(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext, WireError> {
        self.add(a, b)
    }
    fn pir_rotate(&self, a: &Ciphertext, k: usize) -> Result<Ciphertext, WireError> {
        self.rotate(a, k)
    }
    fn pir_swap_rows(&self, a: &Ciphertext) -> Result<Ciphertext, WireError> {
        self.conjugate(a)
    }
}

/// Encrypt a table of integers (one slot each, `values.len() <= n`) —
/// the data-owner side of the workload. Unused slots are zero, which is
/// absorbing under the selector product.
pub fn encrypt_table(
    ctx: &BfvContext,
    enc: &BfvEncryptor,
    values: &[i64],
    rng: &mut Pcg64,
) -> Ciphertext {
    assert!(values.len() <= ctx.params.slots(), "table larger than the slot count");
    enc.encrypt_slots(ctx, values, rng)
}

/// Encrypt the one-hot selector for `index` — the querying-client side.
/// The index never leaves the client in the clear.
pub fn encrypt_selector(
    ctx: &BfvContext,
    enc: &BfvEncryptor,
    index: usize,
    rng: &mut Pcg64,
) -> Ciphertext {
    let slots = ctx.params.slots();
    assert!(index < slots, "selector index out of range");
    let mut sel = vec![0i64; slots];
    sel[index] = 1;
    enc.encrypt_slots(ctx, &sel, rng)
}

/// The server-side lookup: selector–table product, then the full
/// rotate-and-sum reduction (row swap + log2(n/2) rotations). Every slot
/// of the result holds `table[index] mod t`, exactly. `slots` is the BFV
/// slot count `n`.
pub fn pir_lookup<E: PirEngine>(
    engine: &E,
    selector: &Ciphertext,
    table: &Ciphertext,
    slots: usize,
) -> Result<Ciphertext, E::Error> {
    assert!(slots.is_power_of_two() && slots >= 2);
    let mut acc = engine.pir_mul(selector, table)?;
    // Fold row 1 onto row 0 (and vice versa): after this, column j holds
    // the sum of both rows' column j.
    let swapped = engine.pir_swap_rows(&acc)?;
    acc = engine.pir_add(&acc, &swapped)?;
    // Rotate-and-sum within the rows: doubling strides cover all n/2
    // columns in log2(n/2) rounds — the same power-of-two orbit
    // `rotate_and_sum_steps` declares keys for.
    let half = slots / 2;
    let mut k = 1usize;
    while k < half {
        let rot = engine.pir_rotate(&acc, k)?;
        acc = engine.pir_add(&acc, &rot)?;
        k <<= 1;
    }
    Ok(acc)
}

/// The plaintext reference the encrypted lookup must match exactly:
/// `table[index] mod t` (entries outside the table read as 0).
pub fn pir_reference(table: &[i64], index: usize, t: u64) -> u64 {
    table
        .get(index)
        .map(|&v| v.rem_euclid(t as i64) as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bfv::{BfvKeyGen, BfvParams};
    use std::sync::Arc;

    #[test]
    fn local_lookup_is_exact_at_every_index() {
        let ctx = BfvContext::new(BfvParams::toy());
        let mut rng = Pcg64::new(0x91B);
        let kg = BfvKeyGen::new(&ctx, &mut rng);
        let keys = Arc::new(kg.eval_key_set(&ctx, &ctx.serving_spec(), &mut rng));
        let ev = BfvEvaluator::new(&ctx, keys);
        let enc = kg.encryptor();
        let dec = kg.decryptor();
        let t = ctx.t();
        let slots = ctx.params.slots();
        let table: Vec<i64> = (0..slots as i64).map(|i| (i * 104729 + 17) % t as i64).collect();
        let table_ct = encrypt_table(&ctx, &enc, &table, &mut rng);
        // A spread of indices including both batching rows and the edges.
        for index in [0usize, 1, slots / 2 - 1, slots / 2, slots - 1] {
            let sel = encrypt_selector(&ctx, &enc, index, &mut rng);
            let out = pir_lookup(&ev, &sel, &table_ct, slots).unwrap();
            let back = dec.decrypt_slots(&ctx, &out);
            let want = pir_reference(&table, index, t);
            // Every slot carries the answer — check them all.
            assert!(
                back.iter().all(|&v| v == want),
                "index {index}: got {:?}..., want {want}",
                &back[..4]
            );
        }
    }

    #[test]
    fn lookup_leaves_positive_noise_budget() {
        let ctx = BfvContext::new(BfvParams::toy());
        let mut rng = Pcg64::new(0x91C);
        let kg = BfvKeyGen::new(&ctx, &mut rng);
        let keys = Arc::new(kg.eval_key_set(&ctx, &ctx.serving_spec(), &mut rng));
        let ev = BfvEvaluator::new(&ctx, keys);
        let enc = kg.encryptor();
        let table: Vec<i64> = (0..ctx.params.slots() as i64).collect();
        let table_ct = encrypt_table(&ctx, &enc, &table, &mut rng);
        let sel = encrypt_selector(&ctx, &enc, 3, &mut rng);
        let out = pir_lookup(&ev, &sel, &table_ct, ctx.params.slots()).unwrap();
        let budget = kg.decryptor().noise_budget(&ctx, &out);
        assert!(budget > 10.0, "post-lookup budget {budget}");
    }
}
