//! End-to-end workload builders at the paper's Table V parameters:
//! Bootstrapping, Logistic-Regression training, ResNet20 inference and
//! BERT-Tiny inference. Each builder compiles the application's CKKS op
//! graph into the kernel-launch trace the corresponding FIDESlib program
//! would execute, using `codegen::Compiler` for the primitive expansions.
//!
//! Op-count derivations are documented inline; they follow the reference
//! implementations the paper cites (CHKKS bootstrapping, Han-style LR,
//! Rovida's ResNet20, JKLS matmuls + Chebyshev nonlinearities for
//! BERT-Tiny). DESIGN.md records these as modelled approximations.

use crate::codegen::{Backend, Compiler, SimParams};
use crate::isa::Trace;

pub mod pir;

/// Table V rows.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadParams {
    pub log_n: u32,
    pub l: usize,
    pub dnum: usize,
    pub l_eff: usize,
    pub log_qp: u32,
    pub lambda: u32,
}

pub const BOOTSTRAP: WorkloadParams =
    WorkloadParams { log_n: 16, l: 26, dnum: 3, l_eff: 6, log_qp: 1743, lambda: 128 };
pub const LR: WorkloadParams =
    WorkloadParams { log_n: 16, l: 29, dnum: 4, l_eff: 6, log_qp: 1675, lambda: 128 };
pub const RESNET20: WorkloadParams =
    WorkloadParams { log_n: 16, l: 26, dnum: 4, l_eff: 8, log_qp: 1714, lambda: 128 };
pub const BERT_TINY: WorkloadParams =
    WorkloadParams { log_n: 16, l: 26, dnum: 5, l_eff: 7, log_qp: 1740, lambda: 128 };

impl WorkloadParams {
    pub fn alpha(&self) -> usize {
        (self.l + 1).div_ceil(self.dnum)
    }

    pub fn sim_at(&self, level: usize) -> SimParams {
        SimParams {
            n: 1usize << self.log_n,
            l: level + 1,
            alpha: self.alpha(),
            dnum: self.dnum,
        }
    }
}

/// A workload trace builder bound to one backend.
pub struct Workload {
    pub c: Compiler,
    pub p: WorkloadParams,
}

impl Workload {
    pub fn new(p: WorkloadParams, backend: Backend) -> Self {
        Self { c: Compiler::new(backend), p }
    }

    // ------------------------------------------------------------------
    // Bootstrapping (SVI-B, Fig. 8)
    // ------------------------------------------------------------------

    /// Depth the sine-evaluation pipeline consumes (Taylor seed + 2
    /// squarings + r=6 doublings + final scale — the r used at paper scale).
    pub const EVALMOD_LEVELS: usize = 9;

    /// CHKKS bootstrap with the CoeffToSlot/SlotToCoeff DFT factored into
    /// `fft_iter` sparse stages (the Fig. 8 sweep knob).
    ///
    /// Per stage of radix `r = slots^(1/fft_iter)`: a BSGS linear
    /// transform with ~2*sqrt(r) rotations, r diagonal PtMults and r-1
    /// additions, consuming one level. EvalMod runs twice (real/imag
    /// split via one conjugation each).
    pub fn bootstrap(&self, fft_iter: usize) -> Trace {
        let slots = (1usize << self.p.log_n) / 2;
        let radix = (slots as f64).powf(1.0 / fft_iter as f64).ceil() as usize;
        let bsgs_rot = 2 * (radix as f64).sqrt().ceil() as usize;

        let mut t = Trace::default();
        let mut level = self.p.l;

        // ModRaise: limb re-expansion, elementwise over the full chain.
        t.extend(self.c.ptadd(&self.p.sim_at(level)));

        // CoeffToSlot stages.
        for _ in 0..fft_iter {
            let sp = self.p.sim_at(level);
            for _ in 0..bsgs_rot {
                t.extend(self.c.rotate(&sp));
            }
            for _ in 0..radix {
                t.extend(self.c.ptmult(&sp));
            }
            for _ in 0..radix.saturating_sub(1) {
                t.extend(self.c.headd(&sp));
            }
            t.extend(self.c.scalar_ops(&sp, 6)); // BSGS scale fixes
            level -= 1;
        }

        // EvalMod on both halves (conjugation = 1 rotation each).
        for _ in 0..2 {
            let sp = self.p.sim_at(level);
            t.extend(self.c.rotate(&sp)); // conjugate
            let mut l = level;
            // u, u^2, u^4, sin/cos seeds, doublings, final scale:
            for step in 0..Self::EVALMOD_LEVELS {
                let spl = self.p.sim_at(l);
                t.extend(self.c.hemult(&spl));
                if step % 2 == 0 {
                    t.extend(self.c.ptmult(&spl));
                }
                t.extend(self.c.headd(&spl));
                // scale-management / constant-fold passes (Fig. 1 scalar)
                t.extend(self.c.scalar_ops(&spl, 4));
                l -= 1;
            }
        }
        level -= Self::EVALMOD_LEVELS;

        // SlotToCoeff stages.
        for _ in 0..fft_iter {
            let sp = self.p.sim_at(level);
            for _ in 0..bsgs_rot {
                t.extend(self.c.rotate(&sp));
            }
            for _ in 0..radix {
                t.extend(self.c.ptmult(&sp));
            }
            level -= 1;
        }
        t
    }

    /// Levels a bootstrap at `fft_iter` consumes; the limbs that remain
    /// determine the *effective* bootstrap time of Fig. 8.
    pub fn bootstrap_levels_used(&self, fft_iter: usize) -> usize {
        2 * fft_iter + Self::EVALMOD_LEVELS
    }

    pub fn limbs_remaining(&self, fft_iter: usize) -> usize {
        self.p.l.saturating_sub(self.bootstrap_levels_used(fft_iter)) + 1
    }

    // ------------------------------------------------------------------
    // Logistic Regression training (downsampled MNIST, 196 features)
    // ------------------------------------------------------------------

    /// One LR epoch over the packed batch: encrypted dot products via
    /// rotate-and-sum (log2(256) rotations), sigmoid via a degree-3
    /// polynomial, and the weight update. 30 iterations + one bootstrap
    /// (Han et al.'s schedule at these parameters).
    pub fn lr_training(&self) -> Trace {
        let mut t = self.bootstrap(5);
        let iters = 30;
        for _ in 0..iters {
            let lvl = 4 + (self.p.l_eff.saturating_sub(4)) / 2; // mid-budget
            let sp = self.p.sim_at(lvl);
            // forward: X^T w — rotate-and-sum over 196->256 features
            for _ in 0..8 {
                t.extend(self.c.rotate(&sp));
                t.extend(self.c.headd(&sp));
            }
            t.extend(self.c.ptmult(&sp));
            // sigmoid(x) ~ a0 + a1 x + a3 x^3: 2 HEMult + 2 PtMult
            t.extend(self.c.hemult(&sp));
            t.extend(self.c.hemult(&sp));
            t.extend(self.c.ptmult(&sp));
            t.extend(self.c.ptmult(&sp));
            // gradient: X (y - p) — another rotate-and-sum + update
            for _ in 0..8 {
                t.extend(self.c.rotate(&sp));
                t.extend(self.c.headd(&sp));
            }
            t.extend(self.c.hemult(&sp));
            t.extend(self.c.headd(&sp));
        }
        t
    }

    // ------------------------------------------------------------------
    // ResNet20 inference (Rovida-style packing)
    // ------------------------------------------------------------------

    /// 20 convolutional layers: each 3x3 conv is 9 rotations + 9 PtMults
    /// per packed channel group (~4 groups), ReLU approximated by a
    /// degree-2 square-based polynomial (2 HEMult), plus 9 bootstraps
    /// across the network (every other layer pair at these parameters).
    pub fn resnet20(&self) -> Trace {
        let mut t = Trace::default();
        for layer in 0..20 {
            let lvl = 3 + (layer % 4); // cycling level budget between boots
            let sp = self.p.sim_at(lvl);
            let groups = 4;
            for _ in 0..groups {
                for _ in 0..9 {
                    t.extend(self.c.rotate(&sp));
                    t.extend(self.c.ptmult(&sp));
                    t.extend(self.c.headd(&sp));
                }
            }
            // ReLU approx
            t.extend(self.c.hemult(&sp));
            t.extend(self.c.hemult(&sp));
            t.extend(self.c.ptadd(&sp));
            // channel-mask + residual + repacking passes (Rovida's
            // encoding does heavy slot masking between layers)
            t.extend(self.c.scalar_ops(&sp, 24));
            if layer % 2 == 1 {
                t.extend(self.bootstrap(5));
            }
        }
        t
    }

    // ------------------------------------------------------------------
    // BERT-Tiny inference (2 encoder layers, d=128, 2 heads, JKLS matmul)
    // ------------------------------------------------------------------

    /// Per encoder layer: QKV + output projections (4 JKLS matmuls at
    /// d=128: ~2*sqrt(d) rotations + d PtMults each), QK^T and PV per head,
    /// softmax (exp via degree-7 Chebyshev + Newton-Raphson reciprocal),
    /// LayerNorm (rotate-sum mean/var + 3 NR iterations), GELU (Chebyshev),
    /// FFN (d->4d->d: 2 matmuls), plus bootstraps between blocks.
    pub fn bert_tiny(&self) -> Trace {
        let mut t = Trace::default();
        let d = 128usize;
        let heads = 2usize;
        let bsgs = 2 * (d as f64).sqrt().ceil() as usize; // 24 rotations
        let sp_at = |l: usize| self.p.sim_at(l);

        // seq_len=128 tokens pack into 4 slot blocks at these parameters
        for _layer in 0..2 {
          for _block in 0..4 {
            let sp = sp_at(5);
            // 4 projection matmuls (JKLS)
            for _ in 0..4 {
                for _ in 0..bsgs {
                    t.extend(self.c.rotate(&sp));
                }
                for _ in 0..d / 4 {
                    t.extend(self.c.ptmult(&sp));
                    t.extend(self.c.headd(&sp));
                }
            }
            // attention scores + weighted values per head
            for _ in 0..heads {
                for _ in 0..bsgs {
                    t.extend(self.c.rotate(&sp));
                }
                for _ in 0..d / 8 {
                    t.extend(self.c.hemult(&sp));
                    t.extend(self.c.headd(&sp));
                }
                // softmax: exp (Chebyshev deg 7 ~ 5 HEMult + 3 PtMult) +
                // reciprocal (3 NR iterations ~ 6 HEMult)
                for _ in 0..11 {
                    t.extend(self.c.hemult(&sp));
                }
                for _ in 0..3 {
                    t.extend(self.c.ptmult(&sp));
                }
            }
            // LayerNorm x2: rotate-sum (log d) + 3 NR sqrt iterations
            for _ in 0..2 {
                for _ in 0..7 {
                    t.extend(self.c.rotate(&sp));
                    t.extend(self.c.headd(&sp));
                }
                for _ in 0..6 {
                    t.extend(self.c.hemult(&sp));
                }
            }
            // FFN: d -> 4d -> d (two matmuls, GELU between)
            for _ in 0..2 {
                for _ in 0..2 * bsgs {
                    t.extend(self.c.rotate(&sp));
                }
                for _ in 0..d / 2 {
                    t.extend(self.c.ptmult(&sp));
                    t.extend(self.c.headd(&sp));
                }
            }
            for _ in 0..8 {
                t.extend(self.c.hemult(&sp)); // GELU Chebyshev
            }
            // mask/shift/scale chains around softmax-LN-GELU
            t.extend(self.c.scalar_ops(&sp, 64));
          }
          // bootstraps to refresh the budget (4 per layer at L_eff=7)
          for _ in 0..4 {
              t.extend(self.bootstrap(5));
          }
        }
        t
    }
}

/// Convenience: build (baseline, fhec) traces for a named workload.
pub fn workload_pair(name: &str) -> (Trace, Trace) {
    let build = |backend: Backend| -> Trace {
        match name {
            "bootstrap" => Workload::new(BOOTSTRAP, backend).bootstrap(5),
            "lr" => Workload::new(LR, backend).lr_training(),
            "resnet20" => Workload::new(RESNET20, backend).resnet20(),
            "bert-tiny" => Workload::new(BERT_TINY, backend).bert_tiny(),
            _ => panic!("unknown workload {name}"),
        }
    };
    (build(Backend::A100), build(Backend::A100Fhec))
}

pub const WORKLOAD_NAMES: [&str; 4] = ["bootstrap", "lr", "resnet20", "bert-tiny"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_v_parameters() {
        assert_eq!(BOOTSTRAP.alpha(), 9);
        assert_eq!(LR.alpha(), 8);
        assert_eq!(RESNET20.alpha(), 7);
        assert_eq!(BERT_TINY.alpha(), 6);
        for p in [BOOTSTRAP, LR, RESNET20, BERT_TINY] {
            assert_eq!(p.log_n, 16);
            assert_eq!(p.lambda, 128);
        }
    }

    #[test]
    fn workload_instruction_ratios_match_table_vi_shape() {
        // Table VI: Bootstrap 2.12x, LR 2.68x, ResNet 1.89x, BERT 1.71x
        // (geomean 1.96x). Our model reproduces the headline shape — every
        // workload compresses by ~2-2.7x — but is flatter across workloads
        // than the paper (the per-workload spread comes from baseline
        // kernel details our calibrated templates average out; see
        // EXPERIMENTS.md). Assert the honest band + geomean proximity.
        let mut geo = 1.0;
        for (name, want) in [
            ("bootstrap", 2.12),
            ("lr", 2.68),
            ("resnet20", 1.89),
            ("bert-tiny", 1.71),
        ] {
            let (base, fhec) = workload_pair(name);
            let r = base.dynamic_instructions() as f64 / fhec.dynamic_instructions() as f64;
            geo *= r;
            println!("{name}: ratio {r:.2} (paper {want})");
            assert!(
                (1.6..=3.0).contains(&r),
                "{name}: ratio {r:.2} outside the paper's band"
            );
        }
        let geo = geo.powf(0.25);
        assert!(
            (geo / 1.96 - 1.0).abs() < 0.35,
            "workload geomean {geo:.2} too far from paper 1.96"
        );
    }

    #[test]
    fn workload_size_ordering_matches_table_vi() {
        // Table VI ordering: Bootstrap < LR < ResNet < BERT.
        let counts: Vec<u64> = WORKLOAD_NAMES
            .iter()
            .map(|n| workload_pair(n).0.dynamic_instructions())
            .collect();
        assert!(counts[0] < counts[1], "bootstrap < lr");
        assert!(counts[1] < counts[2], "lr < resnet");
        assert!(counts[2] < counts[3], "resnet < bert");
    }

    #[test]
    fn fft_iter_sweep_has_interior_optimum() {
        // Fig. 8: the *effective* bootstrap cost (per remaining limb)
        // should be minimized strictly inside the sweep (paper: iter=5).
        let w = Workload::new(BOOTSTRAP, Backend::A100Fhec);
        let eff: Vec<f64> = (2..=6)
            .map(|it| {
                let instr = w.bootstrap(it).dynamic_instructions() as f64;
                instr / w.limbs_remaining(it) as f64
            })
            .collect();
        let best = eff
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        println!("effective instr/limb over iters 2..6: {eff:?} best={}", best + 2);
        assert!(best > 0 && best < 4, "optimum should be interior (got iter={})", best + 2);
    }

    #[test]
    fn bootstrap_levels_accounting() {
        let w = Workload::new(BOOTSTRAP, Backend::A100);
        assert_eq!(w.bootstrap_levels_used(5), 19);
        assert_eq!(w.limbs_remaining(5), 26 - 19 + 1);
    }
}
