//! The FHEC rewrite pass — the paper's "manual trace insertion" (SIV-F).
//!
//! The closed-source nvcc backend cannot emit FHEC.16816, so the paper
//! programs FHECore *as if* it were a Tensor Core and rewrites the trace:
//! every Tensor-Core modmatmul group (Split -> 16x IMMA -> Mid -> 16x IMMA
//! -> Merge, Algorithm 1) collapses into a single FHEC.16816 issue per
//! hardware pass. `codegen` emits both forms natively; this pass exists to
//! *verify* the rewrite relationship between them and to rewrite foreign
//! traces built by hand.

use super::{Instr, KernelLaunch, Opcode, Trace};

/// Rewrite one kernel template: a run of `IMMA.16816 x k` plus its
/// adjacent split/reassembly CUDA-core instructions becomes
/// `FHEC.16816 x (k/16)` — one FHEC per 16 INT8 IMMA passes, the INT32
/// equivalence of SV-A ("a single FHECoreMMM invocation corresponds to 16
/// TensorCoreGEMM calls").
pub fn rewrite_kernel(k: &KernelLaunch) -> KernelLaunch {
    let mut out: Vec<Instr> = Vec::with_capacity(k.template.len());
    let mut i = 0;
    let t = &k.template;
    while i < t.len() {
        let ins = t[i];
        if ins.op == Opcode::Imma16816 {
            // Collapse the IMMA run (and swallow the preceding split /
            // following reassembly INT instructions marked by PRMT).
            let fhec = (ins.repeat / 16).max(1);
            // Drop an immediately preceding PRMT/Shf split block if present.
            while let Some(last) = out.last() {
                if matches!(last.op, Opcode::Prmt | Opcode::Shf | Opcode::Lop3) {
                    out.pop();
                } else {
                    break;
                }
            }
            out.push(Instr::dep(Opcode::Fhec16816, fhec));
            // Swallow the following reassembly block (PRMT/IMAD/ISETP runs
            // up to the next memory/control/matrix instruction).
            let mut j = i + 1;
            while j < t.len()
                && matches!(
                    t[j].op,
                    Opcode::Prmt
                        | Opcode::Imad
                        | Opcode::ImadWide
                        | Opcode::Iadd3
                        | Opcode::Isetp
                        | Opcode::Shf
                        | Opcode::Lop3
                        | Opcode::Sel
                )
            {
                j += 1;
            }
            i = j;
        } else {
            out.push(ins);
            i += 1;
        }
    }
    KernelLaunch {
        name: format!("{}+fhec", k.name),
        template: out,
        ..k.clone()
    }
}

/// Rewrite a whole trace.
pub fn rewrite_trace(t: &Trace) -> Trace {
    Trace {
        launches: t.launches.iter().map(rewrite_kernel).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::isa::KernelClass;

    fn tc_modmatmul_kernel() -> KernelLaunch {
        // The Algorithm 1 structure for one 16x16 tile pair on TCs.
        KernelLaunch {
            name: "ntt_tc".into(),
            class: KernelClass::Ntt,
            ctas: 4,
            warps_per_cta: 8,
            regs_per_thread: 64,
            smem_per_cta: 16384,
            template: vec![
                Instr::x(Opcode::Ldg, 8),
                Instr::x(Opcode::Prmt, 32), // SplitKernel
                Instr::dep(Opcode::Imma16816, 16),
                Instr::x(Opcode::Prmt, 16), // MidKernel: reassemble
                Instr::x(Opcode::ImadWide, 24),
                Instr::x(Opcode::Isetp, 8),
                Instr::dep(Opcode::Imma16816, 16),
                Instr::x(Opcode::Prmt, 16), // MergeKernel
                Instr::x(Opcode::ImadWide, 24),
                Instr::x(Opcode::Isetp, 8),
                Instr::x(Opcode::Stg, 4),
                Instr::new(Opcode::Exit),
            ],
        }
    }

    #[test]
    fn rewrite_collapses_imma_groups() {
        let k = tc_modmatmul_kernel();
        let r = rewrite_kernel(&k);
        let fhec: u64 = r
            .template
            .iter()
            .filter(|i| i.op == Opcode::Fhec16816)
            .map(|i| i.repeat as u64)
            .sum();
        let imma: u64 = r
            .template
            .iter()
            .filter(|i| i.op == Opcode::Imma16816)
            .map(|i| i.repeat as u64)
            .sum();
        assert_eq!(imma, 0, "no IMMA must survive");
        assert_eq!(fhec, 2, "two 16-IMMA passes -> two FHEC issues");
    }

    #[test]
    fn rewrite_shrinks_dynamic_count_substantially() {
        let k = tc_modmatmul_kernel();
        let r = rewrite_kernel(&k);
        let ratio = k.dynamic_instructions() as f64 / r.dynamic_instructions() as f64;
        assert!(ratio > 5.0, "per-modmatmul compression should be large, got {ratio}");
    }

    #[test]
    fn rewrite_preserves_memory_traffic() {
        let k = tc_modmatmul_kernel();
        let r = rewrite_kernel(&k);
        use crate::isa::UnitClass;
        assert_eq!(
            k.instructions_on(UnitClass::MemGlobal),
            r.instructions_on(UnitClass::MemGlobal),
            "LDG/STG must be untouched by the rewrite"
        );
    }

    #[test]
    fn kernels_without_mma_are_untouched() {
        let k = KernelLaunch {
            name: "elementwise".into(),
            class: KernelClass::Elementwise,
            ctas: 2,
            warps_per_cta: 4,
            regs_per_thread: 32,
            smem_per_cta: 0,
            template: vec![
                Instr::x(Opcode::Ldg, 2),
                Instr::x(Opcode::ImadWide, 6),
                Instr::x(Opcode::Stg, 1),
                Instr::new(Opcode::Exit),
            ],
        };
        let r = rewrite_kernel(&k);
        assert_eq!(r.dynamic_instructions(), k.dynamic_instructions());
    }
}
