//! SASS-level instruction model, including the paper's `FHEC.16816`
//! extension (SIV-F).
//!
//! Traces are hierarchical, the way NVBit dumps get replayed in practice:
//! a [`Trace`] is a sequence of [`KernelLaunch`]es; each launch carries the
//! per-warp instruction *template* (what one warp of one CTA executes) plus
//! the grid geometry. Dynamic instruction counts are exact
//! (`template x warps x ctas`); timing comes from `gpusim` which simulates
//! a resident wave cycle-by-cycle and scales across waves.

pub mod rewrite;

/// Functional-unit class an opcode dispatches to (Accel-Sim terminology).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum UnitClass {
    /// INT32 ALU pipeline (IMAD/IADD/ISETP/LOP3/SHF...).
    Int,
    /// FP32 pipeline.
    Fp,
    /// Special function unit.
    Sfu,
    /// Load/store units — global.
    MemGlobal,
    /// Load/store units — shared memory.
    MemShared,
    /// Tensor Core (HMMA/IMMA/DMMA/BMMA).
    TensorCore,
    /// FHECore — the paper's `SPECIALIZED_UNIT_3_OP` mapping (SVI-A).
    FheCore,
    /// Control (BRA/EXIT/BAR).
    Control,
}

/// SASS-level opcodes used by the FHE kernels.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Opcode {
    // CUDA-core integer pipeline
    Imad,
    ImadWide, // 32x32 -> 64 multiply-add (the Barrett workhorse)
    Iadd3,
    Isetp,
    Lop3,
    Shf,
    Sel,
    Mov,
    Prmt, // byte permute — the INT8 split/reassembly instruction
    // FP pipeline (scalar ops)
    Ffma,
    Fmul,
    Fadd,
    // memory
    Ldg,
    Stg,
    Lds,
    Sts,
    // matrix units
    Imma16816,
    /// The proposed extension: 16x8x16 modulo matrix multiply-accumulate
    /// with built-in Barrett reduction (q, mu programmed per instruction).
    Fhec16816,
    // control
    Bar,
    Bra,
    Exit,
}

impl Opcode {
    pub fn unit(self) -> UnitClass {
        use Opcode::*;
        match self {
            Imad | ImadWide | Iadd3 | Isetp | Lop3 | Shf | Sel | Mov | Prmt => UnitClass::Int,
            Ffma | Fmul | Fadd => UnitClass::Fp,
            Ldg | Stg => UnitClass::MemGlobal,
            Lds | Sts => UnitClass::MemShared,
            Imma16816 => UnitClass::TensorCore,
            Fhec16816 => UnitClass::FheCore,
            Bar | Bra | Exit => UnitClass::Control,
        }
    }

    pub fn mnemonic(self) -> &'static str {
        use Opcode::*;
        match self {
            Imad => "IMAD",
            ImadWide => "IMAD.WIDE",
            Iadd3 => "IADD3",
            Isetp => "ISETP",
            Lop3 => "LOP3",
            Shf => "SHF",
            Sel => "SEL",
            Mov => "MOV",
            Prmt => "PRMT",
            Ffma => "FFMA",
            Fmul => "FMUL",
            Fadd => "FADD",
            Ldg => "LDG.E",
            Stg => "STG.E",
            Lds => "LDS",
            Sts => "STS",
            Imma16816 => "IMMA.16816",
            Fhec16816 => "FHEC.16816",
            Bar => "BAR.SYNC",
            Bra => "BRA",
            Exit => "EXIT",
        }
    }
}

/// One warp-level instruction in a kernel template. `repeat` encodes
/// back-to-back issues of the same static instruction (unrolled loops);
/// `dependent` marks a true RAW dependence on the previous instruction
/// (the scoreboard stalls the warp until it completes).
#[derive(Debug, Clone, Copy)]
pub struct Instr {
    pub op: Opcode,
    pub repeat: u32,
    pub dependent: bool,
}

impl Instr {
    pub fn new(op: Opcode) -> Self {
        Self { op, repeat: 1, dependent: false }
    }

    pub fn x(op: Opcode, repeat: u32) -> Self {
        Self { op, repeat, dependent: false }
    }

    pub fn dep(op: Opcode, repeat: u32) -> Self {
        Self { op, repeat, dependent: true }
    }
}

/// The kernel classes of SII-A / Fig. 1 — used for latency/instruction
/// breakdowns per category.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum KernelClass {
    Ntt,
    Intt,
    BaseConv,
    Elementwise,
    Automorphism,
    Other,
}

impl KernelClass {
    pub fn label(self) -> &'static str {
        match self {
            KernelClass::Ntt => "NTT",
            KernelClass::Intt => "INTT",
            KernelClass::BaseConv => "BaseConv",
            KernelClass::Elementwise => "Elementwise",
            KernelClass::Automorphism => "Automorph",
            KernelClass::Other => "Other",
        }
    }

    pub fn all() -> [KernelClass; 6] {
        [
            KernelClass::Ntt,
            KernelClass::Intt,
            KernelClass::BaseConv,
            KernelClass::Elementwise,
            KernelClass::Automorphism,
            KernelClass::Other,
        ]
    }
}

/// One kernel launch: grid geometry + per-warp template.
#[derive(Debug, Clone)]
pub struct KernelLaunch {
    pub name: String,
    pub class: KernelClass,
    pub ctas: u64,
    pub warps_per_cta: u32,
    /// Registers per thread (occupancy limiter, A100: 64k regs/SM).
    pub regs_per_thread: u32,
    /// Shared memory per CTA in bytes (occupancy limiter: 164 KiB/SM).
    pub smem_per_cta: u32,
    pub template: Vec<Instr>,
}

impl KernelLaunch {
    /// Warp-level dynamic instructions of one warp's template.
    pub fn template_len(&self) -> u64 {
        self.template.iter().map(|i| i.repeat as u64).sum()
    }

    /// Exact dynamic warp-instruction count for the whole launch.
    pub fn dynamic_instructions(&self) -> u64 {
        self.template_len() * self.warps_per_cta as u64 * self.ctas
    }

    /// Count instructions hitting a particular unit class.
    pub fn instructions_on(&self, unit: UnitClass) -> u64 {
        let per_warp: u64 = self
            .template
            .iter()
            .filter(|i| i.op.unit() == unit)
            .map(|i| i.repeat as u64)
            .sum();
        per_warp * self.warps_per_cta as u64 * self.ctas
    }

    pub fn total_warps(&self) -> u64 {
        self.warps_per_cta as u64 * self.ctas
    }
}

/// A full application trace (the NVBit-replay substitute).
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub launches: Vec<KernelLaunch>,
}

impl Trace {
    pub fn push(&mut self, k: KernelLaunch) {
        self.launches.push(k);
    }

    pub fn extend(&mut self, other: Trace) {
        self.launches.extend(other.launches);
    }

    /// Scale this trace by `times` loop iterations (exact for counts; the
    /// timing model is linear in waves so it is exact there too).
    pub fn repeated(mut self, times: u64) -> Trace {
        for launch in &mut self.launches {
            launch.ctas *= times;
        }
        self
    }

    pub fn dynamic_instructions(&self) -> u64 {
        self.launches.iter().map(|k| k.dynamic_instructions()).sum()
    }

    pub fn instructions_by_class(&self) -> std::collections::BTreeMap<KernelClass, u64> {
        let mut map = std::collections::BTreeMap::new();
        for k in &self.launches {
            *map.entry(k.class).or_insert(0) += k.dynamic_instructions();
        }
        map
    }

    pub fn instructions_on(&self, unit: UnitClass) -> u64 {
        self.launches.iter().map(|k| k.instructions_on(unit)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_kernel() -> KernelLaunch {
        KernelLaunch {
            name: "toy".into(),
            class: KernelClass::Ntt,
            ctas: 10,
            warps_per_cta: 4,
            regs_per_thread: 32,
            smem_per_cta: 0,
            template: vec![
                Instr::x(Opcode::Ldg, 4),
                Instr::dep(Opcode::Imma16816, 16),
                Instr::x(Opcode::Stg, 2),
                Instr::new(Opcode::Exit),
            ],
        }
    }

    #[test]
    fn dynamic_count_is_template_times_warps() {
        let k = toy_kernel();
        assert_eq!(k.template_len(), 4 + 16 + 2 + 1);
        assert_eq!(k.dynamic_instructions(), 23 * 4 * 10);
    }

    #[test]
    fn unit_class_filtering() {
        let k = toy_kernel();
        assert_eq!(k.instructions_on(UnitClass::TensorCore), 16 * 40);
        assert_eq!(k.instructions_on(UnitClass::MemGlobal), 6 * 40);
        assert_eq!(k.instructions_on(UnitClass::FheCore), 0);
    }

    #[test]
    fn opcode_units() {
        assert_eq!(Opcode::Fhec16816.unit(), UnitClass::FheCore);
        assert_eq!(Opcode::Imma16816.unit(), UnitClass::TensorCore);
        assert_eq!(Opcode::ImadWide.unit(), UnitClass::Int);
        assert_eq!(Opcode::Fhec16816.mnemonic(), "FHEC.16816");
    }

    #[test]
    fn trace_aggregation() {
        let mut t = Trace::default();
        t.push(toy_kernel());
        t.push(toy_kernel());
        assert_eq!(t.dynamic_instructions(), 2 * 23 * 40);
        let by_class = t.instructions_by_class();
        assert_eq!(by_class[&KernelClass::Ntt], 2 * 23 * 40);
    }

    #[test]
    fn repeated_trace_scales_counts() {
        let mut t = Trace::default();
        t.push(toy_kernel());
        let t5 = t.repeated(5);
        assert_eq!(t5.dynamic_instructions(), 5 * 23 * 40);
    }
}
