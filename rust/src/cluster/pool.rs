//! [`ClusterClient`] — the pipelined, shard-aware twin of
//! `wire::RemoteEvaluator`.
//!
//! One [`ShardConn`] per backend holds a dedicated socket with a reader
//! thread that matches protocol-v2 responses to in-flight requests by
//! id, so any number of ops can be in flight per shard (bounded by
//! [`ClusterOptions::window`]). Ops are routed over the consistent-hash
//! [`HashRing`] by their routing key; `Busy` bounces — and v5
//! `Overloaded` bounces from a shard whose tenant key budget is
//! exhausted — are resent on the capped-exponential
//! [`wire::busy_backoff_delay_jittered`] schedule (per-connection
//! deterministic seed, so shards fronting many cluster clients see
//! desynchronized retries); a shard whose connection dies is marked dead
//! and its unfinished ops **fail over** to the next ring replica —
//! correct because `PushKeys` replicates the evaluation keys to every
//! shard, and bit-exact because CKKS evaluation is deterministic.
//!
//! Multi-tenancy: `push_keys`/`push_keys_blob` registers the blob as a
//! tenant on every shard and pins this client to it; the `_as` submit
//! variants carry an explicit per-request tenant id (the gateway path).
//!
//! The synchronous surface (`mul`/`rotate`/`conjugate`/`hom_linear`/
//! `add`/`rescale`/...) mirrors the local `Evaluator`, so every example
//! pipeline runs unchanged against one node or a cluster; the pipelined
//! surface is `submit` (returns a ticket id immediately) + `wait`
//! (id-matched completion, in any order).

use std::collections::HashMap;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::ring::{HashRing, DEFAULT_VNODES};
use crate::ckks::linear::SlotMatrix;
use crate::ckks::params::{CkksContext, CkksParams};
use crate::ckks::program::{FheProgram, ProgramError};
use crate::ckks::{Ciphertext, EvalKeySet, Evaluator, MissingKey, RnsPoly};
use crate::coordinator::MetricsSnapshot;
use crate::telemetry::SpanEvent;
use crate::wire::client::connect_handshake;
use crate::wire::codec::encode_eval_key_set;
use crate::wire::protocol::{encode_op_request, encode_program_request, error_code};
use crate::wire::{
    busy_backoff_delay_jittered, fnv1a64, params_fingerprint, Frame, Message, WireError, WireOp,
};

/// Tuning for the pipelined cluster client.
#[derive(Debug, Clone)]
pub struct ClusterOptions {
    /// Max ops in flight per shard; `submit` blocks beyond this.
    pub window: usize,
    /// Virtual nodes per shard on the routing ring.
    pub vnodes: usize,
    /// `Busy` retry schedule (shared shape with `RemoteEvaluator`).
    pub busy_retries: u32,
    pub busy_backoff: Duration,
    pub busy_backoff_cap: Duration,
    /// How long to retry refused/unreachable sockets at connect time.
    pub connect_timeout: Duration,
}

impl Default for ClusterOptions {
    fn default() -> Self {
        Self {
            window: 16,
            vnodes: DEFAULT_VNODES,
            busy_retries: 50,
            busy_backoff: Duration::from_millis(1),
            busy_backoff_cap: Duration::from_millis(50),
            connect_timeout: Duration::from_secs(15),
        }
    }
}

/// Everything that can go wrong talking to the cluster.
#[derive(Debug)]
pub enum ClusterError {
    Wire(WireError),
    /// The op's key set lacks a key it needs (typed, from the shard).
    MissingKey(MissingKey),
    /// A program failed the shard's typed admission/execution check.
    Program(ProgramError),
    /// A shard answered with a typed error frame.
    Remote { shard: String, code: u16, detail: String },
    /// Every ring replica for the op is dead.
    AllShardsDown,
    /// `Busy` retries exhausted on the owning shard.
    Busy { shard: String, depth: u32 },
    /// A shard acknowledged a key blob whose fingerprint differs from
    /// what was pushed — replication is not bit-identical.
    KeyMismatch { shard: String, got: u64, want: u64 },
    /// Shards disagree on the installed key count.
    KeyCountSkew { counts: Vec<(String, u32)> },
    Protocol(String),
}

impl std::fmt::Display for ClusterError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClusterError::Wire(e) => write!(f, "{e}"),
            ClusterError::MissingKey(mk) => write!(f, "{mk}"),
            ClusterError::Program(e) => write!(f, "program rejected: {e}"),
            ClusterError::Remote { shard, code, detail } => {
                write!(f, "shard {shard} error {code}: {detail}")
            }
            ClusterError::AllShardsDown => write!(f, "every ring replica is down"),
            ClusterError::Busy { shard, depth } => {
                write!(f, "shard {shard} busy ({depth} in flight), retries exhausted")
            }
            ClusterError::KeyMismatch { shard, got, want } => write!(
                f,
                "shard {shard} installed key blob {got:#018x}, pushed {want:#018x}"
            ),
            ClusterError::KeyCountSkew { counts } => {
                write!(f, "shards disagree on key count: {counts:?}")
            }
            ClusterError::Protocol(why) => write!(f, "cluster protocol violation: {why}"),
        }
    }
}

impl std::error::Error for ClusterError {}

impl From<WireError> for ClusterError {
    fn from(e: WireError) -> Self {
        ClusterError::Wire(e)
    }
}

impl From<MissingKey> for ClusterError {
    fn from(mk: MissingKey) -> Self {
        ClusterError::MissingKey(mk)
    }
}

/// One completed op as the shard reported it (mirrors `OpResponse`).
#[derive(Debug, Clone)]
pub struct OpOutcome {
    pub result: Result<Ciphertext, MissingKey>,
    pub service_us: u64,
    pub sim_base_us: f64,
    pub sim_fhec_us: f64,
    pub batch_size: u32,
}

/// One completed program as the shard reported it (mirrors
/// `ProgramResponse`).
#[derive(Debug, Clone)]
pub struct ProgramOutcome {
    pub result: Result<Vec<Ciphertext>, ProgramError>,
    pub service_us: u64,
    pub sim_base_us: f64,
    pub sim_fhec_us: f64,
    pub batch_size: u32,
}

/// A surfaced failover: which op moved, from where, to where.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FailoverEvent {
    pub id: u64,
    pub from: String,
    pub to: String,
}

/// Terminal per-op outcomes recorded by the reader thread.
enum OpResult {
    Done(OpOutcome),
    /// A program ticket completed (whole DAG, one response).
    Program(ProgramOutcome),
    /// Busy retries exhausted at this depth.
    BusyExhausted(u32),
    Remote { code: u16, detail: String },
}

struct PendingOp {
    frame: Arc<Frame>,
    attempts: u32,
    /// `Some(when)`: bounced `Busy`, resend once `when` passes.
    resend_at: Option<Instant>,
}

#[derive(Default)]
struct ConnState {
    inflight: HashMap<u64, PendingOp>,
    done: HashMap<u64, OpResult>,
    keys_ack: Option<(u32, u64)>,
    /// Per-shard metrics breakdown (v3 `ShardMetricsResp` mailbox — the
    /// cluster client always asks for the breakdown; a plain shard
    /// answers with one entry named by its listen address).
    shard_metrics: Option<Vec<(String, MetricsSnapshot)>>,
    /// v7 `TraceResp` mailbox: one drained span window + drop counter.
    trace: Option<(Vec<SpanEvent>, u64)>,
    /// An `Error{id: 0}` frame answering the in-progress RPC (bad key
    /// blob, unexpected message...). The shard keeps serving after
    /// sending these — they fail the RPC, not the connection.
    rpc_error: Option<String>,
    /// Set once the socket is gone; every waiter re-routes.
    dead: Option<String>,
}

/// How waiting on one shard for one op ended.
enum WaitOutcome {
    Finished(OpResult),
    /// The connection died before the op completed; the frame (if the op
    /// was still in flight here) is handed back for failover.
    Dead { frame: Option<Arc<Frame>> },
}

/// A pipelined connection to one shard.
struct ShardConn {
    addr: String,
    writer: Mutex<TcpStream>,
    state: Mutex<ConnState>,
    cv: Condvar,
    /// Deterministic jitter seed (from this socket's ephemeral local
    /// address) for the `Busy`/`Overloaded` resend schedule.
    backoff_seed: u64,
    /// Serializes the single-slot RPCs (`PushKeys`, `Metrics`): the
    /// response lands in a one-deep mailbox, so a second concurrent
    /// caller would otherwise clear/steal the first caller's reply.
    rpc: Mutex<()>,
    opts: ClusterOptions,
}

impl ShardConn {
    /// Connect + handshake (synchronously, via the shared
    /// `wire::client::connect_handshake`), then hand the read half to a
    /// reader thread that demultiplexes responses by id.
    fn connect(
        addr: &str,
        fingerprint: u64,
        opts: ClusterOptions,
    ) -> Result<Arc<Self>, WireError> {
        let stream = connect_handshake(addr, fingerprint, opts.connect_timeout)?;
        let backoff_seed = stream
            .local_addr()
            .map(|a| fnv1a64(a.to_string().as_bytes()))
            .unwrap_or_else(|_| fnv1a64(addr.as_bytes()));
        let reader = BufReader::new(stream.try_clone()?);
        let conn = Arc::new(Self {
            addr: addr.to_string(),
            writer: Mutex::new(stream),
            state: Mutex::new(ConnState::default()),
            cv: Condvar::new(),
            backoff_seed,
            rpc: Mutex::new(()),
            opts,
        });
        let rc = conn.clone();
        std::thread::spawn(move || rc.reader_loop(reader));
        Ok(conn)
    }

    fn reader_loop(&self, mut reader: BufReader<TcpStream>) {
        loop {
            let msg = match Frame::read_from(&mut reader).and_then(|f| Message::decode(&f)) {
                Ok(m) => m,
                Err(e) => {
                    self.mark_dead(format!("read failed: {e}"));
                    return;
                }
            };
            let mut st = self.state.lock().unwrap();
            match msg {
                Message::OpResponse {
                    id,
                    result,
                    service_us,
                    sim_base_us,
                    sim_fhec_us,
                    batch_size,
                } => {
                    st.inflight.remove(&id);
                    st.done.insert(
                        id,
                        OpResult::Done(OpOutcome {
                            result,
                            service_us,
                            sim_base_us,
                            sim_fhec_us,
                            batch_size,
                        }),
                    );
                }
                Message::Busy { id, depth } => {
                    // A bounced op stays in its window slot (it is still
                    // the client's to deliver) but is scheduled for a
                    // jittered capped-exponential resend, serviced by
                    // whichever thread waits on this connection next.
                    if let Some(p) = st.inflight.get_mut(&id) {
                        if p.attempts >= self.opts.busy_retries {
                            st.inflight.remove(&id);
                            st.done.insert(id, OpResult::BusyExhausted(depth));
                        } else {
                            let delay = busy_backoff_delay_jittered(
                                self.backoff_seed,
                                p.attempts,
                                self.opts.busy_backoff,
                                self.opts.busy_backoff_cap,
                            );
                            p.attempts += 1;
                            p.resend_at = Some(Instant::now() + delay);
                        }
                    }
                }
                Message::Error { id, code, detail } => {
                    if id != 0 && code == error_code::OVERLOADED && st.inflight.contains_key(&id)
                    {
                        // The shard's tenant key budget is transiently
                        // exhausted: resend like a Busy bounce, floored
                        // at the server-suggested retry-after.
                        let p = st.inflight.get_mut(&id).unwrap();
                        if p.attempts >= self.opts.busy_retries {
                            st.inflight.remove(&id);
                            st.done.insert(id, OpResult::Remote { code, detail });
                        } else {
                            let floor =
                                Duration::from_millis(detail.parse::<u64>().unwrap_or(0));
                            let delay = busy_backoff_delay_jittered(
                                self.backoff_seed,
                                p.attempts,
                                self.opts.busy_backoff,
                                self.opts.busy_backoff_cap,
                            )
                            .max(floor);
                            p.attempts += 1;
                            p.resend_at = Some(Instant::now() + delay);
                        }
                    } else if id != 0 && st.inflight.remove(&id).is_some() {
                        st.done.insert(id, OpResult::Remote { code, detail });
                    } else {
                        // id-0 errors answer an RPC (e.g. a bad PushKeys
                        // blob) — the shard stays up and keeps serving,
                        // so fail the RPC, never the connection. If the
                        // shard considered the stream unusable it closes
                        // it, which we observe as EOF above.
                        st.rpc_error = Some(format!("remote error {code}: {detail}"));
                    }
                }
                Message::ProgramResponse {
                    id,
                    result,
                    service_us,
                    sim_base_us,
                    sim_fhec_us,
                    batch_size,
                } => {
                    st.inflight.remove(&id);
                    st.done.insert(
                        id,
                        OpResult::Program(ProgramOutcome {
                            result,
                            service_us,
                            sim_base_us,
                            sim_fhec_us,
                            batch_size,
                        }),
                    );
                }
                Message::KeysAck { keys, fingerprint } => {
                    st.keys_ack = Some((keys, fingerprint));
                }
                Message::ShardMetricsResp(shards) => {
                    st.shard_metrics = Some(shards);
                }
                Message::TraceResp { events, dropped } => {
                    st.trace = Some((events, dropped));
                }
                // Anything else is noise at this layer.
                _ => {}
            }
            self.cv.notify_all();
        }
    }

    fn mark_dead(&self, why: String) {
        let mut st = self.state.lock().unwrap();
        if st.dead.is_none() {
            st.dead = Some(why);
        }
        self.cv.notify_all();
    }

    fn is_dead(&self) -> bool {
        self.state.lock().unwrap().dead.is_some()
    }

    fn write_frame(&self, frame: &Frame) -> Result<(), String> {
        let mut w = self.writer.lock().unwrap();
        frame
            .write_to(&mut *w)
            .and_then(|()| w.flush().map_err(WireError::Io))
            .map_err(|e| e.to_string())
    }

    /// Service one due `Busy` resend under the caller's lock, or report
    /// how long until the earliest scheduled one. Returns the reacquired
    /// guard and whether a resend happened (callers then re-check state
    /// from the top). Both the window-blocked submitter and waiters run
    /// this, so bounced ops make progress no matter which side is
    /// parked.
    fn pump_resends<'a>(
        &'a self,
        mut st: std::sync::MutexGuard<'a, ConnState>,
    ) -> (std::sync::MutexGuard<'a, ConnState>, bool) {
        let now = Instant::now();
        let mut due: Option<Arc<Frame>> = None;
        let mut earliest: Option<Instant> = None;
        for p in st.inflight.values_mut() {
            if let Some(at) = p.resend_at {
                if at <= now {
                    p.resend_at = None;
                    due = Some(p.frame.clone());
                    break;
                }
                earliest = Some(earliest.map_or(at, |e: Instant| e.min(at)));
            }
        }
        if let Some(frame) = due {
            drop(st);
            if let Err(why) = self.write_frame(&frame) {
                self.mark_dead(why);
            }
            return (self.state.lock().unwrap(), true);
        }
        let st = match earliest {
            Some(at) => self.cv.wait_timeout(st, at - now).unwrap().0,
            // Re-check periodically as a belt-and-braces against a
            // missed wakeup; the reader thread notifies on every state
            // change, including death.
            None => self.cv.wait_timeout(st, Duration::from_millis(500)).unwrap().0,
        };
        (st, false)
    }

    /// Register `id` in the window (blocking while the window is full,
    /// servicing due resends meanwhile) and send its frame. `Err` means
    /// this shard cannot take the op — the caller fails over.
    fn send_op(&self, id: u64, frame: Arc<Frame>) -> Result<(), String> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(why) = &st.dead {
                return Err(why.clone());
            }
            if st.inflight.len() < self.opts.window {
                break;
            }
            st = self.pump_resends(st).0;
        }
        st.inflight
            .insert(id, PendingOp { frame: frame.clone(), attempts: 0, resend_at: None });
        drop(st);
        if let Err(why) = self.write_frame(&frame) {
            self.state.lock().unwrap().inflight.remove(&id);
            self.mark_dead(why.clone());
            return Err(why);
        }
        Ok(())
    }

    /// Block until `id` completes on this connection (servicing due
    /// `Busy` resends for *any* op here while waiting) or the
    /// connection dies.
    fn wait_op(&self, id: u64) -> WaitOutcome {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = st.done.remove(&id) {
                self.cv.notify_all(); // a window slot freed
                return WaitOutcome::Finished(r);
            }
            if st.dead.is_some() {
                let frame = st.inflight.remove(&id).map(|p| p.frame);
                return WaitOutcome::Dead { frame };
            }
            st = self.pump_resends(st).0;
        }
    }

    /// Synchronous `PushKeys` round trip; returns `(count, blob fp)`.
    /// Serialized via `self.rpc`; times out rather than waiting forever
    /// on a reply that will never come (the mailbox is one-deep).
    fn push_keys_blob(&self, blob: Vec<u8>) -> Result<(u32, u64), String> {
        let _rpc = self.rpc.lock().unwrap();
        {
            let mut st = self.state.lock().unwrap();
            st.keys_ack = None;
            st.rpc_error = None;
        }
        self.write_frame(&Message::PushKeys { blob }.encode())
            .inspect_err(|why| self.mark_dead(why.clone()))?;
        // Generous: the shard decodes + re-expands the whole key set
        // before acking.
        self.await_mailbox(Duration::from_secs(120), "KeysAck", |st| st.keys_ack.take())
    }

    /// Synchronous per-shard metrics round trip (serialized via
    /// `self.rpc`). A plain shard answers with one entry; a gateway with
    /// its whole downstream breakdown.
    fn fetch_shard_metrics(&self) -> Result<Vec<(String, MetricsSnapshot)>, String> {
        let _rpc = self.rpc.lock().unwrap();
        {
            let mut st = self.state.lock().unwrap();
            st.shard_metrics = None;
            st.rpc_error = None;
        }
        self.write_frame(&Message::ShardMetricsReq.encode())
            .inspect_err(|why| self.mark_dead(why.clone()))?;
        self.await_mailbox(Duration::from_secs(15), "ShardMetricsResp", |st| {
            st.shard_metrics.take()
        })
    }

    /// Synchronous v7 trace drain (serialized via `self.rpc`).
    fn fetch_trace(&self) -> Result<(Vec<SpanEvent>, u64), String> {
        let _rpc = self.rpc.lock().unwrap();
        {
            let mut st = self.state.lock().unwrap();
            st.trace = None;
            st.rpc_error = None;
        }
        self.write_frame(&Message::TraceReq.encode())
            .inspect_err(|why| self.mark_dead(why.clone()))?;
        self.await_mailbox(Duration::from_secs(15), "TraceResp", |st| st.trace.take())
    }

    /// Wait for a one-deep RPC mailbox to fill, with a deadline.
    fn await_mailbox<T>(
        &self,
        timeout: Duration,
        what: &str,
        mut take: impl FnMut(&mut ConnState) -> Option<T>,
    ) -> Result<T, String> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(v) = take(&mut st) {
                return Ok(v);
            }
            if let Some(why) = st.rpc_error.take() {
                return Err(why);
            }
            if let Some(why) = &st.dead {
                return Err(why.clone());
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(format!("timed out waiting for {what} from {}", self.addr));
            }
            let wait = (deadline - now).min(Duration::from_millis(500));
            st = self.cv.wait_timeout(st, wait).unwrap().0;
        }
    }
}

/// Per-cluster metrics: one snapshot per shard plus the summed view.
#[derive(Debug, Clone)]
pub struct ClusterMetrics {
    /// `(shard address, snapshot)`; dead shards are omitted.
    pub shards: Vec<(String, MetricsSnapshot)>,
}

impl ClusterMetrics {
    /// The cluster-wide sum (lane depths and served counters added,
    /// means served-weighted).
    pub fn total(&self) -> MetricsSnapshot {
        let mut out = MetricsSnapshot::default();
        for (_, snap) in &self.shards {
            out.absorb(snap);
        }
        out
    }
}

/// The shard-aware, pipelined cluster client.
pub struct ClusterClient {
    conns: Vec<Arc<ShardConn>>,
    ring: HashRing,
    /// In-flight ticket bookkeeping: id -> (routing key, conn index).
    route: Mutex<HashMap<u64, (u64, usize)>>,
    next_id: AtomicU64,
    fingerprint: u64,
    /// Tenant id this client's requests are issued under (set by
    /// `push_keys`; 0 = each shard's most recently registered tenant).
    tenant: AtomicU64,
    local: Evaluator,
    failovers: Mutex<Vec<FailoverEvent>>,
}

impl ClusterClient {
    /// Connect to every shard and handshake. `addrs` are the ring names:
    /// the same list (in any order per-entry, but identical strings)
    /// yields the identical routing everywhere.
    pub fn connect(
        addrs: &[String],
        params: CkksParams,
        opts: ClusterOptions,
    ) -> Result<Self, ClusterError> {
        assert!(!addrs.is_empty(), "cluster needs at least one shard");
        let fingerprint = params_fingerprint(&params);
        let mut conns = Vec::with_capacity(addrs.len());
        for addr in addrs {
            conns.push(ShardConn::connect(addr, fingerprint, opts.clone())?);
        }
        Ok(Self {
            conns,
            ring: HashRing::new(addrs, opts.vnodes),
            route: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            fingerprint,
            tenant: AtomicU64::new(0),
            local: Evaluator::without_keys(CkksContext::new(params)),
            failovers: Mutex::new(Vec::new()),
        })
    }

    /// The negotiated parameter-set fingerprint.
    pub fn fingerprint(&self) -> u64 {
        self.fingerprint
    }

    /// The tenant id this client's requests are issued under (0 until
    /// the first `push_keys` or an explicit `set_tenant`).
    pub fn tenant(&self) -> u64 {
        self.tenant.load(Ordering::Relaxed)
    }

    /// Issue subsequent requests under this tenant id (a key-blob
    /// fingerprint; 0 = each shard's most recently registered tenant).
    pub fn set_tenant(&self, tenant: u64) {
        self.tenant.store(tenant, Ordering::Relaxed);
    }

    /// The shared CKKS context.
    pub fn ctx(&self) -> &CkksContext {
        &self.local.ctx
    }

    /// The embedded key-less evaluator for client-side plaintext ops —
    /// same contract as `RemoteEvaluator::local`.
    pub fn local(&self) -> &Evaluator {
        &self.local
    }

    /// Addresses of shards whose connection is still up.
    pub fn live_shards(&self) -> Vec<String> {
        self.conns
            .iter()
            .filter(|c| !c.is_dead())
            .map(|c| c.addr.clone())
            .collect()
    }

    /// The shard address `key` routes to (ignoring liveness) — the
    /// deterministic ring placement.
    pub fn route_of(&self, key: u64) -> &str {
        &self.conns[self.ring.route(key)].addr
    }

    /// Every failover that happened so far (typed, in order).
    pub fn failover_events(&self) -> Vec<FailoverEvent> {
        self.failovers.lock().unwrap().clone()
    }

    pub fn failovers(&self) -> usize {
        self.failovers.lock().unwrap().len()
    }

    fn record_failover(&self, id: u64, from: usize, to: usize) {
        let ev = FailoverEvent {
            id,
            from: self.conns[from].addr.clone(),
            to: self.conns[to].addr.clone(),
        };
        eprintln!(
            "cluster: failover op {} from {} to {}",
            ev.id, ev.from, ev.to
        );
        self.failovers.lock().unwrap().push(ev);
    }

    /// Serialize the key set once and replicate it to **every** shard —
    /// each registers it as the tenant `fnv1a64(blob)` — verifying each
    /// `KeysAck` echoes the identical blob fingerprint and key count:
    /// after this, any shard can serve any op for this tenant, which is
    /// what makes failover safe. Pins this client to the new tenant.
    pub fn push_keys(&self, keys: &EvalKeySet) -> Result<u32, ClusterError> {
        self.push_keys_blob(&encode_eval_key_set(keys, self.fingerprint, true))
    }

    /// Replicate an already-encoded key blob (the gateway path: bytes
    /// are forwarded verbatim, never re-encoded).
    pub fn push_keys_blob(&self, blob: &[u8]) -> Result<u32, ClusterError> {
        let want = fnv1a64(blob);
        let mut counts = Vec::with_capacity(self.conns.len());
        for conn in &self.conns {
            let (keys, got) = conn.push_keys_blob(blob.to_vec()).map_err(|why| {
                ClusterError::Remote {
                    shard: conn.addr.clone(),
                    code: 0,
                    detail: why,
                }
            })?;
            if got != want {
                return Err(ClusterError::KeyMismatch {
                    shard: conn.addr.clone(),
                    got,
                    want,
                });
            }
            counts.push((conn.addr.clone(), keys));
        }
        if counts.windows(2).any(|w| w[0].1 != w[1].1) {
            return Err(ClusterError::KeyCountSkew { counts });
        }
        self.tenant.store(want, Ordering::Relaxed);
        Ok(counts[0].1)
    }

    /// Aggregate metrics across all live shards — per-shard entries, not
    /// just the sum. Behind a gateway the entries are the gateway's
    /// downstream shards (v3 `ShardMetricsResp`), so the breakdown
    /// survives the extra hop.
    pub fn metrics(&self) -> Result<ClusterMetrics, ClusterError> {
        let mut shards = Vec::new();
        for conn in &self.conns {
            if conn.is_dead() {
                continue;
            }
            match conn.fetch_shard_metrics() {
                Ok(list) => shards.extend(list),
                Err(_) => continue, // died mid-request: skip, like dead
            }
        }
        if shards.is_empty() {
            return Err(ClusterError::AllShardsDown);
        }
        Ok(ClusterMetrics { shards })
    }

    /// Drain every live shard's span rings (v7 `TraceReq`) into one
    /// event list, summing the per-shard drop counters. Shard span
    /// timestamps share no epoch — each process measures from its own
    /// start — so the merged list is a union of per-shard timelines, not
    /// a globally ordered one; the per-event `tid` keeps them apart in a
    /// Chrome trace rendering.
    pub fn trace(&self) -> Result<(Vec<SpanEvent>, u64), ClusterError> {
        let mut events = Vec::new();
        let mut dropped = 0u64;
        let mut live = 0usize;
        for conn in &self.conns {
            if conn.is_dead() {
                continue;
            }
            match conn.fetch_trace() {
                Ok((evs, d)) => {
                    events.extend(evs);
                    dropped = dropped.saturating_add(d);
                    live += 1;
                }
                Err(_) => continue, // died mid-request: skip, like dead
            }
        }
        if live == 0 {
            return Err(ClusterError::AllShardsDown);
        }
        Ok((events, dropped))
    }

    /// Ask every shard process to stop accepting and drain.
    pub fn shutdown(&self) -> Result<(), ClusterError> {
        let frame = Message::Shutdown.encode();
        for conn in &self.conns {
            if !conn.is_dead() {
                let _ = conn.write_frame(&frame);
            }
        }
        Ok(())
    }

    /// Pipelined submission routed by the fresh ticket id itself.
    /// Returns the ticket; the op is in flight until [`Self::wait`].
    pub fn submit(
        &self,
        op: &WireOp,
        ct: &Ciphertext,
        ct2: Option<&Ciphertext>,
    ) -> Result<u64, ClusterError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_inner(id, id, op, ct, ct2, self.tenant())
    }

    /// Pipelined submission with an explicit routing key (the gateway
    /// passes the upstream request id, so placement is a deterministic
    /// function of the client-visible id). Ticket ids are still
    /// allocated internally and returned.
    pub fn submit_keyed(
        &self,
        route_key: u64,
        op: &WireOp,
        ct: &Ciphertext,
        ct2: Option<&Ciphertext>,
    ) -> Result<u64, ClusterError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_inner(route_key, id, op, ct, ct2, self.tenant())
    }

    /// [`Self::submit_keyed`] with an explicit per-request tenant id —
    /// the gateway path, where one cluster client multiplexes requests
    /// from many downstream tenants.
    pub fn submit_keyed_as(
        &self,
        route_key: u64,
        tenant: u64,
        op: &WireOp,
        ct: &Ciphertext,
        ct2: Option<&Ciphertext>,
    ) -> Result<u64, ClusterError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        self.submit_inner(route_key, id, op, ct, ct2, tenant)
    }

    /// Pipelined whole-program submission, routed (like ops) by the
    /// ticket id — the ring key of the program's input register stream.
    /// One frame carries the DAG and every input; the shard answers with
    /// one `ProgramResponse` matched by [`Self::wait_program`].
    pub fn submit_program(
        &self,
        prog: &FheProgram,
        inputs: &[Ciphertext],
    ) -> Result<u64, ClusterError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = encode_program_request(id, prog, inputs, self.tenant());
        self.submit_frame(id, id, Arc::new(frame))
    }

    /// [`Self::submit_program`] with an explicit routing key (the
    /// gateway passes the upstream request id).
    pub fn submit_program_keyed(
        &self,
        route_key: u64,
        prog: &FheProgram,
        inputs: &[Ciphertext],
    ) -> Result<u64, ClusterError> {
        self.submit_program_keyed_as(route_key, self.tenant(), prog, inputs)
    }

    /// [`Self::submit_program_keyed`] with an explicit per-request
    /// tenant id (the gateway path).
    pub fn submit_program_keyed_as(
        &self,
        route_key: u64,
        tenant: u64,
        prog: &FheProgram,
        inputs: &[Ciphertext],
    ) -> Result<u64, ClusterError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let frame = encode_program_request(id, prog, inputs, tenant);
        self.submit_frame(route_key, id, Arc::new(frame))
    }

    fn submit_inner(
        &self,
        route_key: u64,
        id: u64,
        op: &WireOp,
        ct: &Ciphertext,
        ct2: Option<&Ciphertext>,
        tenant: u64,
    ) -> Result<u64, ClusterError> {
        self.submit_frame(route_key, id, Arc::new(encode_op_request(id, op, ct, ct2, tenant)))
    }

    /// Place one already-encoded request frame on the ring: the owner
    /// shard if live, else down the replica chain (recorded as a
    /// failover).
    fn submit_frame(
        &self,
        route_key: u64,
        id: u64,
        frame: Arc<Frame>,
    ) -> Result<u64, ClusterError> {
        let owner = self.ring.route(route_key);
        let mut failed_over = false;
        for idx in self.ring.replicas(route_key) {
            if self.conns[idx].is_dead() {
                failed_over = true;
                continue;
            }
            match self.conns[idx].send_op(id, frame.clone()) {
                Ok(()) => {
                    if failed_over {
                        self.record_failover(id, owner, idx);
                    }
                    self.route.lock().unwrap().insert(id, (route_key, idx));
                    return Ok(id);
                }
                Err(_) => {
                    failed_over = true;
                    continue;
                }
            }
        }
        Err(ClusterError::AllShardsDown)
    }

    /// Block until the ticket completes, failing over to the next ring
    /// replica if the owning shard dies mid-flight. Completion order is
    /// whatever the shards produce — ids, not admission order.
    pub fn wait(&self, id: u64) -> Result<OpOutcome, ClusterError> {
        let (idx, r) = self.wait_result(id)?;
        match r {
            OpResult::Done(outcome) => Ok(outcome),
            OpResult::Program(_) => Err(ClusterError::Protocol(format!(
                "ticket {id} completed as a program; use wait_program"
            ))),
            OpResult::BusyExhausted(depth) => Err(ClusterError::Busy {
                shard: self.conns[idx].addr.clone(),
                depth,
            }),
            OpResult::Remote { code, detail } => Err(ClusterError::Remote {
                shard: self.conns[idx].addr.clone(),
                code,
                detail,
            }),
        }
    }

    /// [`Self::wait`] for program tickets: one completion carries every
    /// output of the DAG (or the typed [`ProgramError`]).
    pub fn wait_program(&self, id: u64) -> Result<ProgramOutcome, ClusterError> {
        let (idx, r) = self.wait_result(id)?;
        match r {
            OpResult::Program(outcome) => Ok(outcome),
            OpResult::Done(_) => Err(ClusterError::Protocol(format!(
                "ticket {id} completed as a single op; use wait"
            ))),
            OpResult::BusyExhausted(depth) => Err(ClusterError::Busy {
                shard: self.conns[idx].addr.clone(),
                depth,
            }),
            OpResult::Remote { code, detail } => Err(ClusterError::Remote {
                shard: self.conns[idx].addr.clone(),
                code,
                detail,
            }),
        }
    }

    /// Submit + wait for a whole program — the synchronous whole-DAG
    /// path (`RemoteEvaluator::run_program`'s cluster twin).
    pub fn run_program(
        &self,
        prog: &FheProgram,
        inputs: &[Ciphertext],
    ) -> Result<Vec<Ciphertext>, ClusterError> {
        let id = self.submit_program(prog, inputs)?;
        let outcome = self.wait_program(id)?;
        outcome.result.map_err(ClusterError::Program)
    }

    /// The shared completion/failover loop behind [`Self::wait`] and
    /// [`Self::wait_program`]: returns the finishing connection's index
    /// and the raw result.
    fn wait_result(&self, id: u64) -> Result<(usize, OpResult), ClusterError> {
        loop {
            let (route_key, idx) = *self
                .route
                .lock()
                .unwrap()
                .get(&id)
                .ok_or_else(|| ClusterError::Protocol(format!("unknown ticket {id}")))?;
            match self.conns[idx].wait_op(id) {
                WaitOutcome::Finished(r) => {
                    self.route.lock().unwrap().remove(&id);
                    return Ok((idx, r));
                }
                WaitOutcome::Dead { frame } => {
                    let Some(frame) = frame else {
                        self.route.lock().unwrap().remove(&id);
                        return Err(ClusterError::Protocol(format!(
                            "ticket {id} lost on dead shard {}",
                            self.conns[idx].addr
                        )));
                    };
                    // Re-home the op on the next live replica; the ring
                    // order after the dead owner is the failover chain.
                    let mut moved = false;
                    for next in self.ring.replicas(route_key) {
                        if next == idx || self.conns[next].is_dead() {
                            continue;
                        }
                        if self.conns[next].send_op(id, frame.clone()).is_ok() {
                            self.record_failover(id, idx, next);
                            self.route.lock().unwrap().insert(id, (route_key, next));
                            moved = true;
                            break;
                        }
                    }
                    if !moved {
                        self.route.lock().unwrap().remove(&id);
                        return Err(ClusterError::AllShardsDown);
                    }
                }
            }
        }
    }

    /// Submit + wait — the one-op synchronous path behind the
    /// `Evaluator`-shaped methods.
    fn call(
        &self,
        op: WireOp,
        ct: &Ciphertext,
        ct2: Option<&Ciphertext>,
    ) -> Result<Ciphertext, ClusterError> {
        let id = self.submit(&op, ct, ct2)?;
        let outcome = self.wait(id)?;
        outcome.result.map_err(ClusterError::MissingKey)
    }

    // ------------------------------------------------------------------
    // Table II ops — signatures mirror `Evaluator` / `RemoteEvaluator`
    // ------------------------------------------------------------------

    /// HEMult (with relinearization + rescale), on the owning shard.
    pub fn mul(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::Mul, a, Some(b))
    }

    /// Slot rotation by `k`.
    pub fn rotate(&self, a: &Ciphertext, k: usize) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::Rotate(k), a, None)
    }

    /// Complex conjugation of every slot.
    pub fn conjugate(&self, a: &Ciphertext) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::Conjugate, a, None)
    }

    /// BSGS dense linear transform.
    pub fn hom_linear(
        &self,
        a: &Ciphertext,
        m: &SlotMatrix,
    ) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::HomLinear(m.clone()), a, None)
    }

    /// `a * a` with relinearization.
    pub fn square(&self, a: &Ciphertext) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::Square, a, None)
    }

    /// Encrypted linear scoring against the shard-side model weights.
    pub fn linear_score(&self, a: &Ciphertext) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::LinearScore, a, None)
    }

    /// HEAdd on the owning shard's CUDA-class lane.
    pub fn add(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::Add, a, Some(b))
    }

    /// Ciphertext subtraction on the owning shard's CUDA-class lane.
    pub fn sub(&self, a: &Ciphertext, b: &Ciphertext) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::Sub, a, Some(b))
    }

    /// Negation on the owning shard.
    pub fn negate(&self, a: &Ciphertext) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::Negate, a, None)
    }

    /// Scalar slot product (burns one level).
    pub fn mul_const(&self, a: &Ciphertext, value: f64) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::MulConst(value), a, None)
    }

    /// Scalar slot addition.
    pub fn add_const(&self, a: &Ciphertext, value: f64) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::AddConst(value), a, None)
    }

    /// PtMult with rescale (the plaintext travels inline).
    pub fn mul_plain(&self, a: &Ciphertext, pt: &RnsPoly) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::MulPlain(pt.clone()), a, None)
    }

    /// Exact level drop.
    pub fn level_reduce(&self, a: &Ciphertext, level: usize) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::LevelReduce(level), a, None)
    }

    /// Rescale on the owning shard's CUDA-class lane.
    pub fn rescale(&self, a: &Ciphertext) -> Result<Ciphertext, ClusterError> {
        self.call(WireOp::Rescale, a, None)
    }
}
