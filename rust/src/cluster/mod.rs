//! The cluster subsystem: sharded serving over the wire layer.
//!
//! PR 3 gave the repo a single-node TCP front (`wire::serve`) and a
//! synchronous `RemoteEvaluator`. This module is the scale-out layer the
//! ROADMAP's "millions of users" north star needs — once per-device
//! kernel throughput is fixed, end-to-end FHE serving is bounded by how
//! work is distributed across devices and how much of it is kept in
//! flight (cf. Cheddar, arXiv:2407.13055):
//!
//! * [`ring`] — a consistent-hash ring with virtual nodes and
//!   deterministic FNV+SplitMix64 placement: any process building the
//!   ring from the same shard list routes every session/ciphertext id
//!   identically, and removing one of K shards remaps only the ~1/K of
//!   keys it owned.
//! * [`pool`] — [`ClusterClient`], the pipelined out-of-order client: a
//!   window of in-flight ops per shard, protocol-v2 id-matched
//!   completion, capped-exponential `Busy` backoff (shared schedule with
//!   `RemoteEvaluator`), and failover of unfinished ops to the next ring
//!   replica when a shard connection dies. Evaluation keys are
//!   **replicated** to every shard with per-shard blob-fingerprint
//!   verification, which is exactly what makes failover safe; metrics
//!   aggregate across shards ([`ClusterMetrics`]).
//! * [`gateway`] — `fhecore-gateway`: a wire-protocol server fronting N
//!   `fhecore-serve` backends. Downstream it is indistinguishable from a
//!   single shard, so every existing pipeline (examples, CLI quickstart,
//!   `RemoteEvaluator`) runs unchanged against one node or a cluster.
//!
//! The demo workload helpers at the bottom drive the same mixed
//! FHEC/CUDA-class op list through a cluster synchronously and
//! pipelined, with bit-exactness checked against a local `Evaluator` —
//! shared by `fhecore cluster quickstart`, the `cluster` bench and the
//! loopback integration tests.

pub mod gateway;
pub mod pool;
pub mod ring;

pub use gateway::{serve_gateway, GatewayOptions};
pub use pool::{
    ClusterClient, ClusterError, ClusterMetrics, ClusterOptions, FailoverEvent, OpOutcome,
    ProgramOutcome,
};
pub use ring::HashRing;

use crate::ckks::{Ciphertext, Encryptor, Evaluator};
use crate::util::rng::Pcg64;
use crate::wire::WireOp;

/// A deterministic mixed-class op list with locally computed expected
/// results: `Square` / `Rotate(3)` (FHEC lane) interleaved with `Add` /
/// `Rescale` (CUDA lane), each over a fresh encrypted input.
pub struct DemoWorkload {
    pub ops: Vec<WireOp>,
    pub inputs: Vec<Ciphertext>,
    pub ct2: Vec<Option<Ciphertext>>,
    /// What a local `Evaluator` over the identical key set produces —
    /// remote results must match **bit for bit**.
    pub expected: Vec<Ciphertext>,
}

/// Build an `n_ops`-long workload. `ev` must hold the relin key and the
/// rotation-by-3 key at the top level.
pub fn demo_workload(
    ev: &Evaluator,
    enc: &Encryptor,
    rng: &mut Pcg64,
    n_ops: usize,
) -> DemoWorkload {
    use crate::ckks::encoding::Complex;
    let slots = ev.ctx.params.slots();
    let level = ev.ctx.max_level();
    let mut wl = DemoWorkload {
        ops: Vec::with_capacity(n_ops),
        inputs: Vec::with_capacity(n_ops),
        ct2: Vec::with_capacity(n_ops),
        expected: Vec::with_capacity(n_ops),
    };
    for i in 0..n_ops {
        let z: Vec<Complex> = (0..slots)
            .map(|j| Complex::new(0.01 * ((i + j) % 20) as f64, 0.0))
            .collect();
        let ct = enc.encrypt_slots(&ev.ctx, &z, level, rng);
        let (op, ct2, want) = match i % 4 {
            0 => (WireOp::Square, None, ev.mul(&ct, &ct).expect("relin key")),
            1 => (WireOp::Rotate(3), None, ev.rotate(&ct, 3).expect("rot key")),
            2 => {
                let z2: Vec<Complex> = (0..slots)
                    .map(|j| Complex::new(0.005 * ((2 * i + j) % 10) as f64, 0.0))
                    .collect();
                let c2 = enc.encrypt_slots(&ev.ctx, &z2, level, rng);
                let want = ev.add(&ct, &c2);
                (WireOp::Add, Some(c2), want)
            }
            _ => (WireOp::Rescale, None, ev.rescale(&ct)),
        };
        wl.ops.push(op);
        wl.inputs.push(ct);
        wl.ct2.push(ct2);
        wl.expected.push(want);
    }
    wl
}

/// One-at-a-time execution (submit, wait, next) — the synchronous
/// baseline the pipelined path is benchmarked against.
pub fn run_sync(
    cluster: &ClusterClient,
    wl: &DemoWorkload,
) -> Result<Vec<Ciphertext>, ClusterError> {
    let mut out = Vec::with_capacity(wl.ops.len());
    for i in 0..wl.ops.len() {
        let id = cluster.submit(&wl.ops[i], &wl.inputs[i], wl.ct2[i].as_ref())?;
        out.push(cluster.wait(id)?.result?);
    }
    Ok(out)
}

/// Pipelined execution: every op is submitted before any completion is
/// consumed, and completions are collected in **reverse** submission
/// order — deliberately out of admission order, exercising protocol
/// v2's id-matched delivery. Results are returned in submission order.
pub fn run_pipelined(
    cluster: &ClusterClient,
    wl: &DemoWorkload,
) -> Result<Vec<Ciphertext>, ClusterError> {
    let mut tickets = Vec::with_capacity(wl.ops.len());
    for i in 0..wl.ops.len() {
        tickets.push(cluster.submit(&wl.ops[i], &wl.inputs[i], wl.ct2[i].as_ref())?);
    }
    let mut out: Vec<Option<Ciphertext>> = vec![None; wl.ops.len()];
    for (i, &id) in tickets.iter().enumerate().rev() {
        out[i] = Some(cluster.wait(id)?.result?);
    }
    Ok(out.into_iter().map(|c| c.expect("all waited")).collect())
}
