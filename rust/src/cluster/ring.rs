//! Consistent-hash ring: deterministic FNV placement with virtual nodes.
//!
//! Every shard contributes `vnodes` points on a 64-bit ring; a key is
//! owned by the first point clockwise from its hash. Placement is a pure
//! function of the shard *name* and the vnode index — two processes that
//! build a ring from the same shard list route every key identically,
//! which is what lets a gateway and its clients (or two gateways) agree
//! on ownership without any coordination. Removing one of K shards
//! remaps exactly the keys that shard owned (~1/K of the space); every
//! other key keeps its owner because no other point moves.
//!
//! Raw FNV-1a clusters badly on short, similar inputs ("shard#0",
//! "shard#1", ...), so every placement and key hash is finished with the
//! SplitMix64 avalanche — still fully deterministic, but the points
//! spread uniformly.

use crate::wire::fnv1a64;

/// SplitMix64 finalizer: a cheap, deterministic 64-bit avalanche.
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9e37_79b9_7f4a_7c15);
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// Ring position of one virtual node (pure in `name` and `v`).
fn place(name: &str, v: usize) -> u64 {
    mix64(fnv1a64(format!("{name}#{v}").as_bytes()))
}

/// Default virtual nodes per shard: enough that a 2-shard ring splits
/// the key space within a few percent of evenly.
pub const DEFAULT_VNODES: usize = 128;

/// A consistent-hash ring over named shards.
#[derive(Debug, Clone)]
pub struct HashRing {
    /// `(ring position, shard index)`, sorted ascending.
    points: Vec<(u64, usize)>,
    names: Vec<String>,
    vnodes: usize,
}

impl HashRing {
    /// Build a ring from shard names with `vnodes` points per shard.
    pub fn new(names: &[String], vnodes: usize) -> Self {
        assert!(!names.is_empty(), "ring needs at least one shard");
        assert!(vnodes > 0, "vnodes must be positive");
        let mut ring = Self { points: Vec::new(), names: Vec::new(), vnodes };
        for name in names {
            ring.add_shard(name);
        }
        ring
    }

    /// The shard names, index-aligned with [`Self::route`]'s results.
    pub fn names(&self) -> &[String] {
        &self.names
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    /// Hash a routing key (session / ciphertext / request id) onto the
    /// ring. Canonical little-endian bytes, so every process agrees.
    pub fn key_hash(key: u64) -> u64 {
        mix64(fnv1a64(&key.to_le_bytes()))
    }

    /// Add a shard; returns its index. Only the new shard's `vnodes`
    /// points appear — every existing key either keeps its owner or
    /// moves to the new shard (minimal remap).
    pub fn add_shard(&mut self, name: &str) -> usize {
        assert!(
            !self.names.iter().any(|n| n == name),
            "duplicate shard name {name:?}"
        );
        let idx = self.names.len();
        self.names.push(name.to_string());
        for v in 0..self.vnodes {
            self.points.push((place(name, v), idx));
        }
        self.points.sort_unstable();
        idx
    }

    /// Remove a shard by name. Only the keys it owned remap (to the
    /// next point clockwise); all other owners are untouched. Indices
    /// above the removed shard shift down by one. Returns whether the
    /// shard was present.
    pub fn remove_shard(&mut self, name: &str) -> bool {
        let Some(idx) = self.names.iter().position(|n| n == name) else {
            return false;
        };
        self.names.remove(idx);
        self.points.retain(|&(_, i)| i != idx);
        for p in &mut self.points {
            if p.1 > idx {
                p.1 -= 1;
            }
        }
        true
    }

    /// First point at or clockwise-after `h` (wrapping).
    fn owner_of_hash(&self, h: u64) -> usize {
        let i = self.points.partition_point(|&(p, _)| p < h);
        let i = if i == self.points.len() { 0 } else { i };
        self.points[i].1
    }

    /// The shard index owning `key`.
    pub fn route(&self, key: u64) -> usize {
        self.owner_of_hash(Self::key_hash(key))
    }

    /// Distinct shard indices in ring order starting at `key`'s owner —
    /// the failover sequence: the owner first, then each next shard met
    /// walking clockwise. Length = shard count.
    pub fn replicas(&self, key: u64) -> Vec<usize> {
        let h = Self::key_hash(key);
        let start = self.points.partition_point(|&(p, _)| p < h);
        let mut seen = vec![false; self.names.len()];
        let mut out = Vec::with_capacity(self.names.len());
        for off in 0..self.points.len() {
            let (_, idx) = self.points[(start + off) % self.points.len()];
            if !seen[idx] {
                seen[idx] = true;
                out.push(idx);
                if out.len() == self.names.len() {
                    break;
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn names(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn routing_is_a_pure_function_of_the_shard_list() {
        let a = HashRing::new(&names(&["alpha", "beta", "gamma"]), 16);
        let b = HashRing::new(&names(&["alpha", "beta", "gamma"]), 16);
        for key in 0..4096u64 {
            assert_eq!(a.route(key), b.route(key), "key {key}");
        }
    }

    #[test]
    fn golden_routes_pin_the_cross_process_contract() {
        // Computed by an independent implementation of the spec
        // (FNV-1a 64 over "name#v" / LE key bytes, SplitMix64 finalizer,
        // first point clockwise). Any change to placement or key hashing
        // breaks this vector — and with it, deployed rings.
        let ring = HashRing::new(&names(&["alpha", "beta", "gamma"]), 16);
        let got: Vec<usize> = (0..12u64).map(|k| ring.route(k)).collect();
        assert_eq!(got, vec![1, 2, 2, 1, 1, 0, 2, 0, 2, 1, 2, 2]);
    }

    #[test]
    fn replicas_start_at_owner_and_cover_all_shards() {
        let ring = HashRing::new(&names(&["a", "b", "c", "d"]), 32);
        for key in 0..256u64 {
            let reps = ring.replicas(key);
            assert_eq!(reps[0], ring.route(key), "key {key}");
            let mut sorted = reps.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 4, "replicas must be distinct: {reps:?}");
        }
    }

    #[test]
    fn add_then_remove_is_the_identity() {
        let base = HashRing::new(&names(&["a", "b", "c"]), 32);
        let mut ring = base.clone();
        ring.add_shard("d");
        ring.remove_shard("d");
        for key in 0..2048u64 {
            assert_eq!(ring.route(key), base.route(key), "key {key}");
        }
    }
}
