//! The `fhecore-gateway` engine room: a wire-protocol server that fronts
//! N `fhecore-serve` shards through one [`ClusterClient`].
//!
//! To a downstream client the gateway **is** a shard — same `Hello`
//! handshake, same `PushKeys`/`OpRequest`/`Metrics`/`Shutdown` surface —
//! so `RemoteEvaluator`, `ClusterClient` and every example pipeline run
//! against it unchanged. Behind it:
//!
//! * `PushKeys` blobs are **replicated verbatim** to every shard, each
//!   `KeysAck` fingerprint is compared against the pushed bytes, and a
//!   single ack (count + fingerprint) goes back downstream.
//! * Each `OpRequest` is routed over the consistent-hash ring **by the
//!   upstream request id** (so placement is a deterministic function of
//!   the client-visible id), pipelined into the owning shard's window,
//!   and answered in completion order — a forwarder thread per in-flight
//!   op carries the shard's response back under the upstream id.
//! * `MetricsReq` returns the summed [`MetricsSnapshot`] across shards.
//! * `Shutdown` fans out to every shard, then stops the gateway itself.
//!
//! Backpressure composes: when the owning shard's window is full the
//! gateway's reader blocks on `submit` (TCP pushback upstream), and
//! shard-side `Busy` bounces are absorbed by the cluster client's
//! capped-exponential retries.

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Sender as MpscSender};
use std::sync::Arc;

use super::pool::{ClusterClient, ClusterError, ClusterOptions};
use crate::bfv::BfvParams;
use crate::ckks::params::CkksParams;
use crate::wire::codec::bfv_params_fingerprint;
use crate::wire::protocol::error_code;
use crate::wire::server::{hello_reply, read_inbound, writer_loop, Inbound};
use crate::wire::{params_fingerprint, Message};

#[derive(Debug, Clone)]
pub struct GatewayOptions {
    pub params: CkksParams,
    /// Shard addresses — the ring names; every gateway (and any client
    /// routing directly) must use the identical list.
    pub shards: Vec<String>,
    pub cluster: ClusterOptions,
    pub verbose: bool,
}

struct GatewayShared {
    /// Fingerprints the gateway handshakes for: the CKKS set plus the
    /// matching BFV set (same ring, same chain — the shards behind the
    /// gateway serve both by default, and `PushKeys` blobs replicate
    /// verbatim regardless of scheme).
    fingerprints: [u64; 2],
    cluster: ClusterClient,
    stop: AtomicBool,
    verbose: bool,
}

/// Map a cluster-level failure onto a wire error frame for `op_id` —
/// shard-typed codes pass through **with their detail verbatim** (an
/// `OVERLOADED` detail is the retry-after-ms a downstream client
/// parses; decorating it would break that), everything else (all
/// replicas down...) is a serving failure. `ClusterError::Busy` is
/// handled before this: it stays a typed `Message::Busy`, never an
/// error.
fn cluster_error_message(op_id: u64, e: ClusterError) -> Message {
    match e {
        ClusterError::Remote { code, detail, .. } if code != 0 => {
            Message::Error { id: op_id, code, detail }
        }
        e => Message::Error { id: op_id, code: error_code::STOPPED, detail: e.to_string() },
    }
}

/// Run the gateway on an already-bound listener until a client sends
/// `Shutdown` (which is fanned out to every shard first).
pub fn serve_gateway(listener: TcpListener, opts: GatewayOptions) -> std::io::Result<()> {
    let addr = listener.local_addr()?;
    let cluster =
        ClusterClient::connect(&opts.shards, opts.params.clone(), opts.cluster.clone())
            .map_err(|e| {
                std::io::Error::new(
                    std::io::ErrorKind::ConnectionRefused,
                    format!("cannot reach shards: {e}"),
                )
            })?;
    let shared = Arc::new(GatewayShared {
        fingerprints: [
            params_fingerprint(&opts.params),
            bfv_params_fingerprint(&BfvParams::matching(&opts.params)),
        ],
        cluster,
        stop: AtomicBool::new(false),
        verbose: opts.verbose,
    });
    loop {
        let (stream, peer) = match listener.accept() {
            Ok(x) => x,
            Err(e) => {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                eprintln!("fhecore-gateway: accept failed: {e}");
                continue;
            }
        };
        if shared.stop.load(Ordering::SeqCst) {
            break; // the wake-up connection from a shutting-down handler
        }
        if shared.verbose {
            println!("fhecore-gateway: connection from {peer}");
        }
        let shared = shared.clone();
        std::thread::spawn(move || handle_conn(stream, shared, addr));
    }
    Ok(())
}

fn handle_conn(stream: TcpStream, shared: Arc<GatewayShared>, listen_addr: SocketAddr) {
    let _ = stream.set_nodelay(true);
    let reader_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("fhecore-gateway: cannot split stream: {e}");
            return;
        }
    };
    let (tx, rx) = channel::<Message>();
    let writer = std::thread::spawn(move || writer_loop(stream, rx));
    let shutdown = reader_loop(reader_stream, &shared, &tx);
    drop(tx);
    let _ = writer.join();
    if shutdown {
        if shared.verbose {
            println!("fhecore-gateway: shutdown requested; stopping shards");
        }
        let _ = shared.cluster.shutdown();
        // Unblock the accept loop so `serve_gateway` can return.
        let _ = TcpStream::connect(listen_addr);
    }
}

fn reader_loop(
    stream: TcpStream,
    shared: &Arc<GatewayShared>,
    tx: &MpscSender<Message>,
) -> bool {
    let mut r = std::io::BufReader::new(stream);
    let send = |m: Message| {
        let _ = tx.send(m);
    };
    loop {
        let msg = match read_inbound(&mut r) {
            Inbound::Msg(m) => m,
            Inbound::Gone => return false, // EOF / peer gone
            Inbound::Garbled(err) => {
                send(err);
                continue;
            }
            Inbound::Fatal(err) => {
                send(err);
                return false;
            }
        };
        match msg {
            Message::Hello { version, fingerprint } => {
                match hello_reply(version, fingerprint, &shared.fingerprints, "gateway") {
                    Ok(ack) => send(ack),
                    Err(err) => {
                        send(err);
                        return false;
                    }
                }
            }
            Message::PushKeys { blob } => match shared.cluster.push_keys_blob(&blob) {
                Ok(keys) => {
                    if shared.verbose {
                        println!(
                            "fhecore-gateway: replicated key set ({keys} keys) to {} shards",
                            shared.cluster.live_shards().len()
                        );
                    }
                    send(Message::KeysAck {
                        keys,
                        fingerprint: crate::wire::fnv1a64(&blob),
                    });
                }
                Err(e) => send(Message::Error {
                    id: 0,
                    code: error_code::DECODE,
                    detail: format!("key replication failed: {e}"),
                }),
            },
            Message::OpRequest { id, op, ct, ct2, tenant } => {
                // Route by the upstream id (deterministic placement);
                // block here if the owner's window is full — that TCP
                // pushback *is* the gateway's admission control. The
                // upstream tenant id rides through verbatim: one gateway
                // connection can multiplex many tenants.
                match shared.cluster.submit_keyed_as(id, tenant, &op, &ct, ct2.as_ref()) {
                    Ok(ticket) => {
                        let shared = shared.clone();
                        let tx = tx.clone();
                        std::thread::spawn(move || {
                            let msg = match shared.cluster.wait(ticket) {
                                Ok(o) => Message::OpResponse {
                                    id,
                                    result: o.result,
                                    service_us: o.service_us,
                                    sim_base_us: o.sim_base_us,
                                    sim_fhec_us: o.sim_fhec_us,
                                    batch_size: o.batch_size,
                                },
                                // Exhausted Busy retries are still
                                // transient load, not failure: keep the
                                // typed backpressure signal so a
                                // downstream client retries on its own
                                // schedule instead of aborting.
                                Err(ClusterError::Busy { depth, .. }) => {
                                    Message::Busy { id, depth }
                                }
                                Err(e) => cluster_error_message(id, e),
                            };
                            let _ = tx.send(msg);
                        });
                    }
                    Err(e) => send(cluster_error_message(id, e)),
                }
            }
            Message::ProgramRequest { id, program, inputs, tenant } => {
                // Whole programs route like ops: by the upstream id, to
                // one shard, in one downstream round trip, under the
                // upstream tenant.
                match shared.cluster.submit_program_keyed_as(id, tenant, &program, &inputs) {
                    Ok(ticket) => {
                        let shared = shared.clone();
                        let tx = tx.clone();
                        std::thread::spawn(move || {
                            let msg = match shared.cluster.wait_program(ticket) {
                                Ok(o) => Message::ProgramResponse {
                                    id,
                                    result: o.result,
                                    service_us: o.service_us,
                                    sim_base_us: o.sim_base_us,
                                    sim_fhec_us: o.sim_fhec_us,
                                    batch_size: o.batch_size,
                                },
                                Err(ClusterError::Busy { depth, .. }) => {
                                    Message::Busy { id, depth }
                                }
                                // (Typed program rejections arrive inside
                                // Ok(o).result and pass through above —
                                // wait_program never wraps them itself.)
                                Err(e) => cluster_error_message(id, e),
                            };
                            let _ = tx.send(msg);
                        });
                    }
                    Err(e) => send(cluster_error_message(id, e)),
                }
            }
            Message::MetricsReq => match shared.cluster.metrics() {
                Ok(m) => send(Message::MetricsResp(m.total())),
                Err(e) => send(Message::Error {
                    id: 0,
                    code: error_code::STOPPED,
                    detail: e.to_string(),
                }),
            },
            Message::ShardMetricsReq => match shared.cluster.metrics() {
                // The per-shard breakdown the plain `MetricsReq` sums
                // away — this is what makes shard state visible behind
                // the gateway.
                Ok(m) => send(Message::ShardMetricsResp(m.shards)),
                Err(e) => send(Message::Error {
                    id: 0,
                    code: error_code::STOPPED,
                    detail: e.to_string(),
                }),
            },
            Message::TraceReq => match shared.cluster.trace() {
                // Every shard's drained span window, concatenated, with
                // the drop counters summed — the same fan-out shape as
                // metrics. Each shard timestamps from its own process
                // epoch; the per-event tid keeps the timelines apart.
                Ok((events, dropped)) => send(Message::TraceResp { events, dropped }),
                Err(e) => send(Message::Error {
                    id: 0,
                    code: error_code::STOPPED,
                    detail: e.to_string(),
                }),
            },
            Message::Shutdown => {
                shared.stop.store(true, Ordering::SeqCst);
                return true;
            }
            other => {
                send(Message::Error {
                    id: 0,
                    code: error_code::BAD_REQUEST,
                    detail: format!("unexpected message tag {:#04x}", other.tag()),
                });
            }
        }
    }
}
