//! `fhecore` CLI — leader entrypoint.
//!
//! Subcommands:
//!   table <id>         regenerate a paper figure/table (fig1..t10, headline, all)
//!   simulate <wl>      run a workload trace through the timing model
//!   serve              demo serving loop (batched encrypted scoring);
//!                      with --listen <addr> it becomes a wire TCP server
//!   client <mode>      remote client: quickstart | metrics | trace | shutdown
//!                      (--connect <addr>, --params toy|medium)
//!   cluster <mode>     sharded serving: serve (gateway fronting
//!                      --shards a,b,...) | quickstart (pipelined
//!                      out-of-order workload, bit-exact vs local) |
//!                      metrics | shutdown
//!   runtime            smoke the PJRT artifacts (needs `make artifacts`)
//!   selftest           quick functional pass over the CKKS substrate

use std::sync::Arc;

use fhecore::ckks::encoding::Complex;
use fhecore::ckks::params::{CkksContext, CkksParams};
use fhecore::ckks::{EvalKeySpec, Evaluator, KeyGen};
use fhecore::coordinator::{Coordinator, ModelState, OpKind, Request, ServeConfig};
use fhecore::gpusim::{simulate_trace, GpuConfig};
use fhecore::util::cli::Args;
use fhecore::util::rng::Pcg64;
use fhecore::workloads::workload_pair;

fn main() {
    let args = Args::from_env();
    match args.subcommand.as_deref() {
        Some("table") => {
            let id = args.positional.first().map(|s| s.as_str()).unwrap_or("headline");
            if id == "all" {
                for name in fhecore::tables::ALL {
                    print!("{}", fhecore::tables::by_name(name).unwrap());
                }
            } else {
                match fhecore::tables::by_name(id) {
                    Some(s) => print!("{s}"),
                    None => {
                        eprintln!("unknown table '{id}'; one of: {:?}", fhecore::tables::ALL)
                    }
                }
            }
        }
        Some("simulate") => {
            let wl = args.positional.first().map(|s| s.as_str()).unwrap_or("bootstrap");
            let cfg = GpuConfig::default();
            let (base, fhec) = workload_pair(wl);
            let sb = simulate_trace(&cfg, &base);
            let sf = simulate_trace(&cfg, &fhec);
            println!(
                "{wl}: A100 {:.2} ms ({} instr) | +FHECore {:.2} ms ({} instr) | speedup {:.2}x instr-ratio {:.2}x",
                sb.latency_ms(&cfg),
                sb.total_instructions(),
                sf.latency_ms(&cfg),
                sf.total_instructions(),
                sb.total_cycles() as f64 / sf.total_cycles() as f64,
                sb.total_instructions() as f64 / sf.total_instructions() as f64,
            );
        }
        Some("serve") => {
            if args.opt("listen").is_some() {
                // Wire mode: front the coordinator with the TCP server.
                std::process::exit(fhecore::wire::cli::run_serve(&args));
            }
            let reqs = args.opt_usize("requests", 16);
            serve_demo(reqs);
        }
        Some("client") => {
            std::process::exit(fhecore::wire::cli::run_client(&args));
        }
        Some("cluster") => {
            std::process::exit(fhecore::wire::cli::run_cluster(&args));
        }
        Some("runtime") => {
            let dir = args.opt("artifacts").unwrap_or("artifacts");
            match fhecore::runtime::Engine::load(dir) {
                Ok(engine) => {
                    println!("loaded artifacts: {:?}", engine.names());
                    runtime_smoke(&engine);
                }
                Err(e) => eprintln!("runtime load failed: {e:#}"),
            }
        }
        Some("selftest") => selftest(),
        _ => {
            println!("fhecore — FHECore (CS.AR 2026) reproduction");
            println!(
                "usage: fhecore <table|simulate|serve|client|cluster|runtime|selftest> [...]"
            );
            println!("  table all | table t8 | simulate bert-tiny | serve --requests 32");
            println!("  serve --listen 127.0.0.1:7009 --params toy   (wire TCP server)");
            println!("  serve --listen ... --key-budget-mb 64 --max-resident-tenants 2");
            println!("                                               (multi-tenant key budget)");
            println!("  serve --listen ... --trace on --slow-request-ms 50");
            println!("                                               (span tracer + slow log)");
            println!("  client quickstart --connect 127.0.0.1:7009   (remote pipeline)");
            println!("  client quickstart --seed 7                   (push a distinct tenant)");
            println!("  client metrics | client shutdown             (ops RPCs)");
            println!("  client trace --out trace.json                (Chrome trace-event dump)");
            println!("  cluster serve --listen 127.0.0.1:7050 --shards a,b  (gateway)");
            println!("  cluster quickstart --connect 127.0.0.1:7050  (pipelined, OOO)");
            println!("  cluster metrics | cluster shutdown           (cluster ops)");
        }
    }
}

fn serve_demo(requests: usize) {
    println!("building CKKS context (N=4096)...");
    let ctx = CkksContext::new(CkksParams::medium());
    let mut rng = Pcg64::new(0xD15EA5E);
    // Client side: secret key + public evaluation keys, generated once.
    // LinearScore's PtMult rescales before the rotate-and-sum, so the
    // rotation keys are consumed one level below the request level —
    // declare both.
    let keygen = KeyGen::new(&ctx, &mut rng);
    let keys = keygen.eval_key_set(
        &ctx,
        &EvalKeySpec::serving(ctx.params.slots())
            .at_levels(vec![ctx.max_level(), ctx.max_level() - 1]),
        &mut rng,
    );
    let enc = keygen.encryptor();
    // Server side: evaluator + workers hold only the public key set.
    let ev = Arc::new(Evaluator::new(ctx, Arc::new(keys)));
    let slots = ev.ctx.params.slots();
    let w: Vec<Complex> =
        (0..slots).map(|i| Complex::new(0.002 * (i % 50) as f64, 0.0)).collect();
    let weights_pt = ev.encode(&w, ev.ctx.max_level());
    let model = Arc::new(ModelState { weights_pt, rot_steps: slots });
    let coord = Coordinator::start(ev.clone(), model, ServeConfig::default());

    let t0 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for id in 0..requests as u64 {
        let z: Vec<Complex> = (0..slots)
            .map(|i| Complex::new(0.001 * ((i + id as usize) % 100) as f64, 0.0))
            .collect();
        let ct = enc.encrypt_slots(&ev.ctx, &z, ev.ctx.max_level(), &mut rng);
        let mut req = Request::new(id, OpKind::LinearScore, ct);
        // Bounded queue: on backpressure, wait briefly and resubmit.
        let rx = loop {
            match coord.submit(req) {
                Ok(rx) => break rx,
                Err((bounced, e)) => {
                    println!("backpressure on request {id}: {e}; retrying");
                    std::thread::sleep(std::time::Duration::from_millis(2));
                    req = bounced;
                }
            }
        };
        rxs.push(rx);
    }
    let mut sim_base = 0.0;
    let mut sim_fhec = 0.0;
    for rx in rxs {
        let r = rx.recv().unwrap();
        r.ct.expect("serving key set covers LinearScore");
        sim_base += r.sim_base_us;
        sim_fhec += r.sim_fhec_us;
    }
    let wall = t0.elapsed();
    println!(
        "served {requests} encrypted linear-scoring requests in {:.2?} ({:.1} req/s)",
        wall,
        requests as f64 / wall.as_secs_f64()
    );
    println!(
        "mean batch {:.1}, mean service {:.1} us; simulated A100 {:.0} us vs +FHECore {:.0} us ({:.2}x)",
        coord.metrics.mean_batch(),
        coord.metrics.mean_service_us(),
        sim_base,
        sim_fhec,
        sim_base / sim_fhec
    );
    let snap = coord.snapshot();
    println!(
        "lane split: fhec served {} (depth {}), cuda served {} (depth {})",
        snap.fhec_served, snap.fhec_depth, snap.cuda_served, snap.cuda_depth
    );
}

fn runtime_smoke(engine: &fhecore::runtime::Engine) {
    use fhecore::runtime::tables::build_ntt_inputs;
    let q = fhecore::ckks::prime::pe_primes(256, 1)[0];
    let t = build_ntt_inputs(256, 16, q);
    let mut rng = Pcg64::new(1);
    let a: Vec<u32> = (0..256).map(|_| rng.below(q) as u32).collect();
    let out = engine
        .run_u32(
            "ntt_256",
            &[
                a.clone(),
                t.psi_pows.clone(),
                t.w1.clone(),
                t.tw.clone(),
                t.w2.clone(),
                vec![t.q],
                vec![t.mu],
            ],
        )
        .expect("ntt_256 execution");
    // cross-check against the rust NTT
    let table = fhecore::ckks::NttTable::with_psi(
        256,
        q,
        fhecore::ckks::prime::root_of_unity(512, q),
    );
    let mut want: Vec<u64> = a.iter().map(|&x| x as u64).collect();
    table.forward(&mut want);
    let ok = out.iter().zip(&want).all(|(&g, &w)| g as u64 == w);
    println!("ntt_256 PJRT vs rust NTT: {}", if ok { "MATCH" } else { "MISMATCH" });
}

fn selftest() {
    let ctx = CkksContext::new(CkksParams::toy());
    let mut rng = Pcg64::new(7);
    let keygen = KeyGen::new(&ctx, &mut rng);
    let keys = keygen.eval_key_set(&ctx, &EvalKeySpec::relin_only(), &mut rng);
    let enc = keygen.encryptor();
    let dec = keygen.decryptor();
    let ev = Evaluator::new(ctx, Arc::new(keys));
    let slots = ev.ctx.params.slots();
    let z: Vec<Complex> =
        (0..slots).map(|i| Complex::new(0.1 * (i % 5) as f64, 0.0)).collect();
    let ct = enc.encrypt_slots(&ev.ctx, &z, 3, &mut rng);
    let sq = ev.mul(&ct, &ct).expect("relin key generated");
    let back = dec.decrypt_to_slots(&ev.ctx, &sq);
    let err = back
        .iter()
        .enumerate()
        .map(|(i, c)| (c.re - (0.1 * (i % 5) as f64).powi(2)).abs())
        .fold(0.0f64, f64::max);
    println!(
        "selftest: HEMult max error {err:.2e} ({})",
        if err < 1e-3 { "OK" } else { "FAIL" }
    );
}
